"""Observability artifacts of a self-timed run.

Everything the engine measures lands in one `SelfTimedReport`:

* per-channel occupancy high-water marks and stall attribution (how many
  times a process parked — or, under the ``"concurrent"`` policy, how many
  process-steps it spent parked — because this channel was empty / full);
* per-process fire/stall timelines (first/last fire, fire count, stalls
  broken down by channel, and an optional per-step character timeline);
* throughput (fires per step) and the **critical cycle** — the strongly
  connected component of the process graph whose channels absorbed the most
  stall time;
* on deadlock, a `DeadlockInfo`: the blocked set, the blocking cycle in the
  wait-for graph, and the culprit channel.

The report serializes into `AnalysisReport` (``"selftimed"`` field, schema
v3) via `as_dict` and renders for humans via `render` (the
``python -m repro.runtime.selftimed --report`` CLI).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional


@dataclass
class ChannelStats:
    """One bounded channel's observed behavior."""

    name: str
    capacity: Optional[int]         # None = unbounded (ample) run
    values: int                     # distinct tokens the producer emits
    pushes: int                     # tokens actually pushed before stopping
    high_water: int                 # peak occupancy observed
    stall_empty: int = 0            # consumer parked: no token available
    stall_full: int = 0             # producer parked: no free slot

    @property
    def stalls(self) -> int:
        return self.stall_empty + self.stall_full

    def as_dict(self) -> Dict[str, Any]:
        return {"name": self.name, "capacity": self.capacity,
                "values": self.values, "pushes": self.pushes,
                "high_water": self.high_water,
                "stall_empty": self.stall_empty,
                "stall_full": self.stall_full}


@dataclass
class ProcessStats:
    """One process's fire/stall account."""

    name: str
    instances: int
    fires: int = 0
    first_fire: int = -1            # step of first fire (-1: never fired)
    last_fire: int = -1
    stall_in: int = 0               # parked waiting for a token
    stall_out: int = 0              # parked waiting for a slot
    #: scheduling opportunities the actor refused (`EngineHooks.fire_allowed`
    #: returned False) — the observable signature of a stalled/crashed actor
    #: that the resilience watchdog attributes faults by.  Always 0 without
    #: hooks.
    denials: int = 0
    stall_channels: Dict[str, int] = field(default_factory=dict)

    @property
    def stalls(self) -> int:
        return self.stall_in + self.stall_out

    def as_dict(self) -> Dict[str, Any]:
        return {"name": self.name, "instances": self.instances,
                "fires": self.fires, "first_fire": self.first_fire,
                "last_fire": self.last_fire, "stall_in": self.stall_in,
                "stall_out": self.stall_out, "denials": self.denials,
                "stall_channels": dict(self.stall_channels)}


@dataclass
class DeadlockInfo:
    """Structural deadlock evidence: no process can fire, tokens pending.

    ``cycle`` is the blocking cycle in the wait-for graph (a blocked process
    waits on the producer of its empty input / the consumer of its full
    output); ``culprit`` names the channel whose capacity binds — the full
    channel of smallest capacity on the cycle, or the starved channel when
    the chain ends in a finished process (malformed dataflow)."""

    step: int
    fires: int
    pending: int                    # instances that never fired
    blocked: List[Dict[str, Any]]   # {process, kind, channel, occupancy, capacity}
    cycle: List[Dict[str, Any]]     # same entries, the blocking cycle only
    culprit: Optional[str]

    def cycle_channels(self) -> List[str]:
        return [e["channel"] for e in self.cycle]

    def summary(self) -> str:
        path = " -> ".join(f"{e['process']}[{e['kind']}:{e['channel']}"
                           f" {e['occupancy']}/{e['capacity']}]"
                           for e in self.cycle) or "no cycle (starvation)"
        return (f"deadlock at step {self.step} after {self.fires} fires, "
                f"{self.pending} instances pending; blocking cycle: {path}; "
                f"culprit channel: {self.culprit}")

    def as_dict(self) -> Dict[str, Any]:
        return {"step": self.step, "fires": self.fires,
                "pending": self.pending, "blocked": list(self.blocked),
                "cycle": list(self.cycle), "culprit": self.culprit}


@dataclass
class SelfTimedReport:
    """The artifact of one self-timed execution."""

    kernel: str
    policy: str                     # "sequential" | "concurrent"
    steps: int
    fires: int
    total_instances: int
    completed: bool
    cyclic: bool                    # process graph has a cycle
    channels: List[ChannelStats]
    processes: List[ProcessStats]
    deadlock: Optional[DeadlockInfo] = None
    critical_cycle: Optional[Dict[str, Any]] = None
    timeline: Optional[Dict[str, str]] = None   # per-process step chars
    #: processes that fired below the running max joint rank (sequential
    #: policy only) — the linearization could not serialize them, so their
    #: adjacent channels' high-water marks may differ from the trace
    #: simulator's exact peaks.  Empty for a fully linearized run.
    out_of_order: List[str] = field(default_factory=list)

    @property
    def throughput(self) -> float:
        """Fires per step — 1.0 under the sequential policy by construction,
        the degree of overlap under the concurrent policy."""
        return self.fires / self.steps if self.steps else 0.0

    @property
    def total_stalls(self) -> int:
        return sum(p.stalls for p in self.processes)

    @property
    def stall_ratio(self) -> float:
        """Stalled process-steps over scheduled process-steps."""
        denom = self.fires + self.total_stalls
        return self.total_stalls / denom if denom else 0.0

    def high_water(self) -> Dict[str, int]:
        return {c.name: c.high_water for c in self.channels}

    def channel(self, name: str) -> ChannelStats:
        for c in self.channels:
            if c.name == name:
                return c
        raise KeyError(name)

    def stalls_on(self, name: str) -> int:
        return self.channel(name).stalls

    def as_dict(self) -> Dict[str, Any]:
        return {
            "kernel": self.kernel, "policy": self.policy,
            "steps": self.steps, "fires": self.fires,
            "total_instances": self.total_instances,
            "completed": self.completed, "cyclic": self.cyclic,
            "throughput": round(self.throughput, 4),
            "stall_ratio": round(self.stall_ratio, 4),
            "channels": [c.as_dict() for c in self.channels],
            "processes": [p.as_dict() for p in self.processes],
            "deadlock": (None if self.deadlock is None
                         else self.deadlock.as_dict()),
            "critical_cycle": self.critical_cycle,
            "out_of_order": list(self.out_of_order),
        }

    def summary(self) -> str:
        state = "completed" if self.completed else "DEADLOCK"
        return (f"{self.kernel} [{self.policy}]: {state} — "
                f"{self.fires}/{self.total_instances} fires in "
                f"{self.steps} steps (throughput {self.throughput:.2f}, "
                f"stall {100 * self.stall_ratio:.1f}%)")

    def render(self) -> str:
        """Multi-section human rendering (the ``--report`` CLI output)."""
        out = [self.summary(), "", "channels:"]
        out.append(f"  {'name':40s} {'cap':>5s} {'high':>5s} {'push':>6s} "
                   f"{'st.in':>6s} {'st.out':>6s}")
        for c in self.channels:
            cap = "inf" if c.capacity is None else str(c.capacity)
            out.append(f"  {c.name:40s} {cap:>5s} {c.high_water:5d} "
                       f"{c.pushes:6d} {c.stall_empty:6d} {c.stall_full:6d}")
        out.append("")
        out.append("processes:")
        out.append(f"  {'name':24s} {'fires':>7s} {'first':>6s} {'last':>6s} "
                   f"{'st.in':>6s} {'st.out':>6s}")
        for p in self.processes:
            out.append(f"  {p.name:24s} {p.fires:7d} {p.first_fire:6d} "
                       f"{p.last_fire:6d} {p.stall_in:6d} {p.stall_out:6d}")
        if self.critical_cycle is not None:
            cc = self.critical_cycle
            out.append("")
            out.append(f"critical cycle ({' -> '.join(cc['processes'])}), "
                       f"{cc['stalls']} stalls:")
            for c in cc["channels"]:
                out.append(f"  {c['name']:40s} cap {c['capacity']} "
                           f"high {c['high_water']} stalls {c['stalls']}")
        if self.deadlock is not None:
            out.append("")
            out.append(self.deadlock.summary())
        if self.timeline:
            out.append("")
            out.append("timeline (F fire, i wait-token, o wait-slot, . done):")
            width = max(len(n) for n in self.timeline)
            for name, line in self.timeline.items():
                out.append(f"  {name:{width}s} |{line}|")
        return "\n".join(out)
