"""Executable channel runtime: the lowering IR, its backends, and the
operational validation stage (`docs/runtime.md`).

    lowering   — lowering vocabulary, THE verdict→lowering table, registry
    simulator  — trace-driven reference backend (vectorized replay)
    validate   — `Analysis.validate()`: every verdict executed, both ways
    jax_backend — collective implementations (loaded lazily; imports jax)
    pallas_backend / pallas_codegen — VMEM-ring kernels: trace replay
                  through real scratch rings + the whole-PPN compiler
                  behind `Analysis.compile(backend="pallas")` (lazy; the
                  `RingOverflow` exception lives there, jax-importing)
    selftimed   — dataflow-driven execution engine: bounded back-pressured
                  channels, deadlock detection, stall observability
                  (`Analysis.validate(mode="selftimed")`; loaded lazily as
                  the ``"selftimed"`` registry backend; `docs/selftimed.md`)
    resilience  — fault injection + self-healing channel guards over the
                  engine's hook seam: seeded `FaultPlan`s, sequence-tag /
                  checksum / watchdog guards, bounded replay recovery,
                  FIFO→reorder-buffer hot-swap degradation
                  (`Analysis.validate(mode="faults")`; loaded lazily;
                  `docs/resilience.md`)
"""
from .lowering import (BROADCAST_REGISTER, CHUNK_SPLIT, DEPTH_SPLIT,
                       FIFO_STREAM, LOWERINGS, PATTERN_LOWERING,
                       REORDER_BUFFER, Backend, BackendUnavailable,
                       ChannelLowering, available_backends, backend,
                       backend_names, is_cheap, is_stream,
                       lowering_for_pattern, register_backend,
                       split_lowering)
from .simulator import (ChannelTrace, OrderViolation, SimulationError,
                        channel_late_edges, simulate_channel, trace_channel)
from .validate import (ChannelValidation, ValidationError, ValidationReport,
                       validate_analysis)

__all__ = [
    "BROADCAST_REGISTER", "Backend", "BackendUnavailable", "CHUNK_SPLIT",
    "ChannelLowering", "ChannelTrace", "ChannelValidation", "DEPTH_SPLIT",
    "FIFO_STREAM", "LOWERINGS", "OrderViolation", "PATTERN_LOWERING",
    "REORDER_BUFFER", "SimulationError", "ValidationError",
    "ValidationReport", "available_backends", "backend", "backend_names",
    "channel_late_edges", "is_cheap", "is_stream", "lowering_for_pattern",
    "register_backend", "simulate_channel", "split_lowering",
    "trace_channel", "validate_analysis",
]
