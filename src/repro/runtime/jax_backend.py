"""The ``"jax"`` backend: collective implementations of the lowering IR.

Each `ChannelLowering` here builds the per-tick communication step of a
rotating shard_map ring (`comm/pipeline.py`) from the primitives in
`comm/channels.py`:

* every ppermute-family lowering is one neighbor-stream hop — the recovered
  split variants and the broadcast register all ride the same cheap
  `lax.ppermute` link (the register is consumer-local: the received value is
  simply reused across ticks);
* the reorder buffer publishes every shard's value (`lax.all_gather`) and
  dynamically indexes the producer's slot — the expensive lowering the
  paper's algorithm exists to avoid, kept as the measured baseline.

This module imports jax (via `comm.channels`); it is loaded lazily by the
registry (`backend("jax")`) so the analysis core stays jax-free.
"""
from __future__ import annotations

from ..comm.channels import fifo_shift, reorder_buffer_read
from .lowering import (BROADCAST_REGISTER, CHUNK_SPLIT, DEPTH_SPLIT,
                       FIFO_STREAM, REORDER_BUFFER, ChannelLowering,
                       register_backend)

JAX = register_backend("jax")


@JAX.register(FIFO_STREAM, DEPTH_SPLIT, CHUNK_SPLIT, BROADCAST_REGISTER)
class PpermuteRing(ChannelLowering):
    """FIFO neighbor stream: one `lax.ppermute` hop per tick."""

    def step(self, h, axis: str, stage, n: int):
        return fifo_shift(h, axis, 1, wrap=True)


@JAX.register(REORDER_BUFFER)
class ReorderBufferRing(ChannelLowering):
    """Out-of-order fallback: all_gather + dynamic index of the producer."""

    def step(self, h, axis: str, stage, n: int):
        return reorder_buffer_read(h, axis, (stage - 1) % n)
