"""Trace-driven reference PPN simulator — the ``"reference"`` backend.

Every planned channel implementation is *executed* here against the channel's
dataflow trace: producer/consumer events replay in global-schedule (lex-rank)
order through the implementation the plan selected — a strict FIFO queue for
FIFO verdicts, an in-order broadcast register for in-order+multiplicity, an
addressable reorder buffer for out-of-order — raising on any pop the
implementation cannot serve and tracking peak occupancy.

The replay is **vectorized**, not per-event Python: traces are built from the
per-process joint global lex ranks already memoized in the analysis'
`SizingContext` (`pair_rank`), so "replaying" a channel is a handful of numpy
array ops over dense integer ranks:

* the *push sequence* is the channel's distinct producer instances (values)
  in write-rank order;
* the *pop sequence* is the edge list sorted by consumer rank (ties resolve
  in queue order — equal ranks are simultaneous);
* a FIFO executes iff every pushed value is popped exactly once, in push
  order; a register tolerates repeated pops of the front value but no
  regression; a reorder buffer accepts any pop order.

The order checks compare producer-local against consumer-local execution
order (restricted to one process, the joint rank IS its local order), so
they are exact for any PPN.  The joint *cross-process* interleaving is the
tiled sequential linearization the sizing model assumes; channels it cannot
serialize (a read ranked before its write — e.g. a consumer whose
rectangular tiling pins a tile coordinate the producer still iterates, as in
symm's ``accupd->cfin``) execute self-timed in reality and are surfaced as
``late_edges`` on the trace rather than failed.

Peak occupancy comes from an event sweep (+1 at a value's write, −1 after its
last read, reads draining before writes at equal rank) implemented as a
lexsort + cumulative sum — deliberately a *different* code path from the
bincount sweep in `core/sizing.py`, so `Analysis.validate()` cross-checks the
two implementations value-for-value.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from ..core.patterns import _lex_rank
from ..core.ppn import PPN, Channel
from ..core.sizing import SizingContext
from .lowering import (BROADCAST_REGISTER, CHUNK_SPLIT, DEPTH_SPLIT,
                       FIFO_STREAM, REORDER_BUFFER, ChannelLowering,
                       register_backend)


class SimulationError(RuntimeError):
    """The planned implementation could not execute the channel's trace."""

    def __init__(self, channel: str, detail: str):
        super().__init__(f"{channel}: {detail}")
        self.channel = channel
        self.detail = detail


class OrderViolation(SimulationError):
    """A pop arrived that the (FIFO / register) front could not serve."""


@dataclass
class ChannelTrace:
    """One channel's replayable event trace, in dense joint-rank form.

    ``pops`` is the per-edge *push position* of the popped value, in pop
    (consumer-rank) order — the exact sequence a queue implementation sees.
    """

    channel: str
    num_values: int                 # distinct producer instances
    num_edges: int
    w_rank: np.ndarray              # per-edge producer joint rank
    r_rank: np.ndarray              # per-edge consumer joint rank
    value_wrank: np.ndarray         # per-value write rank
    value_last_read: np.ndarray     # per-value last-read rank
    pops: np.ndarray                # per-edge push position, pop order

    @property
    def late_mask(self) -> np.ndarray:
        """Per-edge mask of the non-serializable edge set: reads ranked at
        or before their write.  This is THE exemption set — trace replay
        counts these instead of failing them, and the self-timed engine's
        occupancy cross-check exempts exactly the same edges
        (`channel_late_edges`)."""
        return self.r_rank <= self.w_rank

    @property
    def late_edges(self) -> int:
        """Edges the sequential linearization cannot serialize (read ranked
        at or before its write) — served by blocking in a self-timed run."""
        return int(np.count_nonzero(self.late_mask))

    def peak_occupancy(self) -> int:
        """Max live values during replay: event sweep over (write, last-read)
        pairs, reads draining before writes at the same rank (the event key is
        ``2·rank + is_write``, matching the sequential-schedule semantics)."""
        if self.num_values == 0:
            return 0
        keys = np.concatenate([2 * self.value_wrank + 1,
                               2 * self.value_last_read])
        deltas = np.concatenate([
            np.ones(self.num_values, dtype=np.int64),
            -np.ones(self.num_values, dtype=np.int64)])
        occ = np.cumsum(deltas[np.argsort(keys, kind="stable")])
        return int(max(0, occ.max()))


def trace_channel(ppn: PPN, ch: Channel,
                  sizing: Optional[SizingContext] = None) -> ChannelTrace:
    """Build the replay trace from the memoized joint ranks (`pair_rank`)."""
    sizing = sizing if sizing is not None else SizingContext(ppn)
    sizing.ppn = ppn
    n = ch.num_edges
    if n == 0:
        z = np.zeros(0, dtype=np.int64)
        return ChannelTrace(ch.name, 0, 0, z, z, z, z, z)
    jp, jc = sizing.pair_rank(ch.producer, ch.consumer)
    w_rows = sizing.rows_of(ch.producer, ch.src_pts)
    w_rank = jp[w_rows]
    r_rank = jc[sizing.rows_of(ch.consumer, ch.dst_pts)]
    # values = distinct producer instances (the write rows ARE the identity)
    _, vinv = np.unique(w_rows, return_inverse=True)
    num_values = int(vinv.max()) + 1
    value_wrank = np.empty(num_values, dtype=np.int64)
    value_wrank[vinv] = w_rank              # all edges of a value agree
    order = np.argsort(vinv, kind="stable")
    sorted_v = vinv[order]
    starts = np.concatenate(
        [[0], np.flatnonzero(sorted_v[1:] != sorted_v[:-1]) + 1])
    value_last_read = np.maximum.reduceat(r_rank[order], starts)
    # push position: dense rank of the write rank (ties = simultaneous)
    push_pos = _lex_rank(value_wrank[:, None])
    pops = push_pos[vinv][np.lexsort((push_pos[vinv], r_rank))]
    return ChannelTrace(ch.name, num_values, n, w_rank, r_rank,
                        value_wrank, value_last_read, pops)


def channel_late_edges(ppn: PPN, sizing: Optional[SizingContext] = None
                       ) -> "dict":
    """Per-channel late-edge counts for the whole network — the shared
    exemption set: trace replay reports these per channel (and per split
    part), and `validate(mode="selftimed")` exempts exactly these channels
    from the peak-equality cross-check."""
    sizing = sizing if sizing is not None else SizingContext(ppn)
    return {ch.name: trace_channel(ppn, ch, sizing).late_edges
            for ch in ppn.channels}


REFERENCE = register_backend("reference")


@REFERENCE.register(FIFO_STREAM, DEPTH_SPLIT, CHUNK_SPLIT)
class FifoQueueSim(ChannelLowering):
    """Strict FIFO queue: pops must drain values exactly once, in push order.
    (The split lowerings are the same queue applied to each recovered part —
    `validate` re-splits the channel and replays every part through this.)"""

    def run(self, trace: ChannelTrace) -> int:
        if trace.num_edges != trace.num_values:
            counts = np.bincount(trace.pops, minlength=trace.num_values) \
                if trace.num_edges else np.zeros(trace.num_values, np.int64)
            dup = np.flatnonzero(counts > 1)
            if len(dup):
                d = int(dup[0])
                raise OrderViolation(
                    trace.channel,
                    f"value at push position {d} popped "
                    f"{int(counts[d])} times — a FIFO pop consumes the head")
            gap = int(np.flatnonzero(counts == 0)[0])
            raise OrderViolation(
                trace.channel,
                f"gap: value at push position {gap} was pushed but never "
                f"popped — a FIFO head cannot be skipped")
        regress = np.flatnonzero(np.diff(trace.pops) < 0)
        if len(regress):
            i = int(regress[0])
            raise OrderViolation(
                trace.channel,
                f"out-of-order pop: pop {i + 1} wants push position "
                f"{int(trace.pops[i + 1])} while the head is past "
                f"{int(trace.pops[i])}")
        return trace.peak_occupancy()


@REFERENCE.register(BROADCAST_REGISTER)
class BroadcastRegisterSim(ChannelLowering):
    """In-order broadcast: the front value may be popped repeatedly (local
    multicast register); popping an already-retired value raises."""

    def run(self, trace: ChannelTrace) -> int:
        regress = np.flatnonzero(np.diff(trace.pops) < 0)
        if len(regress):
            i = int(regress[0])
            raise OrderViolation(
                trace.channel,
                f"register reuse after overwrite: pop {i + 1} wants push "
                f"position {int(trace.pops[i + 1])} after the stream "
                f"advanced to {int(trace.pops[i])}")
        return trace.peak_occupancy()


@REFERENCE.register(REORDER_BUFFER)
class ReorderBufferSim(ChannelLowering):
    """Addressable buffer: pops in any order."""

    def run(self, trace: ChannelTrace) -> int:
        return trace.peak_occupancy()


def simulate_channel(ppn: PPN, ch: Channel, lowering: str,
                     sizing: Optional[SizingContext] = None) -> int:
    """Replay one channel through the named lowering on the reference
    backend; returns peak occupancy, raises `SimulationError` when the
    implementation cannot serve the trace."""
    impl = REFERENCE.implementation(lowering)
    return impl.run(trace_channel(ppn, ch, sizing))
