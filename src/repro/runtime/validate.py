"""Operational validation: execute every verdict, don't just trust it.

`Analysis.validate()` lands here.  For each channel of the analyzed PPN the
stage replays the dataflow trace through the implementation the verdict (or
the plan record) selects, in both directions:

* **positive** — the planned implementation must execute the trace: a FIFO
  verdict must pop in order on a strict queue, an in-order+multiplicity
  verdict must stream through the broadcast register, a split plan must
  execute every recovered part on its own FIFO;
* **negative** — a non-FIFO verdict must *fail* on a FIFO queue (and an
  out-of-order verdict must also fail on the register).  A "broken" channel
  that replays cleanly on the cheap implementation means the classifier
  over-approximated — exactly the bug a verdict-driven lowering would turn
  into silent data corruption, caught here instead;
* **occupancy** — the replay's peak occupancy must equal the sizing
  backend's exact capacity (two independent sweep implementations) and fit
  the planned ``size()`` slots.

The order checks are exact for any PPN (they compare per-process local
orders).  Occupancy replays the tiled sequential linearization the sizing
model assumes; edges that linearization cannot serialize (self-timed in a
real run — see `simulator.ChannelTrace.late_edges`) are counted per channel
in the report rather than failed, mirroring how `core/sizing.py` has always
treated them.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from ..core.patterns import Pattern, _classify_channels
from ..core.ppn import PPN, Channel
from ..core.sizing import _channel_capacity, pow2_size
from ..core.split import split_by_tile_pair, split_channel
from .lowering import (CHUNK_SPLIT, DEPTH_SPLIT, FIFO_STREAM,
                       BROADCAST_REGISTER, backend, lowering_for_pattern)
from .simulator import OrderViolation, SimulationError, trace_channel


class ValidationError(AssertionError):
    """At least one verdict or buffer size failed its operational check."""

    def __init__(self, kernel: str, failures: List[str]):
        self.kernel = kernel
        self.failures = list(failures)
        lines = "\n  ".join(failures)
        super().__init__(f"{kernel}: {len(failures)} operational check(s) "
                         f"failed:\n  {lines}")


@dataclass
class ChannelValidation:
    """One channel's operational evidence."""

    name: str
    verdict: str                    # classifier pattern value
    lowering: str                   # implementation the trace replayed on
    parts: int                      # replayed parts (1 unless a split plan)
    peak: int                       # replay peak occupancy (sum over parts)
    capacity: int                   # sizing backend's exact capacity
    slots: int                      # planned slot count checked against
    rejected: Tuple[str, ...] = ()  # lowerings confirmed to FAIL (negative)
    late: int = 0                   # edges the linearization can't serialize
    #: the non-serializable edge set broken down per replayed part — for a
    #: split plan the regenerated parts' counts (previously computed inside
    #: the replay and dropped), for an unsplit channel {name: late}.  This
    #: is what lets selftimed and trace replay agree on which edges are
    #: exempt at part granularity.
    late_parts: Dict[str, int] = field(default_factory=dict)

    def as_dict(self) -> Dict[str, Any]:
        return {"name": self.name, "verdict": self.verdict,
                "lowering": self.lowering, "parts": self.parts,
                "peak": self.peak, "capacity": self.capacity,
                "slots": self.slots, "rejected": list(self.rejected),
                "late": self.late, "late_parts": dict(self.late_parts)}


@dataclass
class ValidationReport:
    """The validate stage's artifact (embedded in `AnalysisReport`)."""

    kernel: str
    backend: str
    channels: List[ChannelValidation] = field(default_factory=list)

    @property
    def replays(self) -> int:
        return sum(c.parts for c in self.channels)

    @property
    def rejections(self) -> int:
        return sum(len(c.rejected) for c in self.channels)

    def as_dict(self) -> Dict[str, Any]:
        return {"backend": self.backend,
                "replays": self.replays, "rejections": self.rejections,
                "channels": [c.as_dict() for c in self.channels]}

    def summary(self) -> str:
        peak = sum(c.peak for c in self.channels)
        slots = sum(c.slots for c in self.channels)
        late = sum(c.late for c in self.channels)
        extra = f", {late} self-timed edges" if late else ""
        return (f"{self.kernel}: {len(self.channels)} channels operationally "
                f"confirmed ({self.replays} replays, {self.rejections} "
                f"negative rejections), peak {peak} <= {slots} slots{extra}")


#: splitter behind each split lowering (regenerates the plan's parts)
_SPLITTERS = {DEPTH_SPLIT: split_channel, CHUNK_SPLIT: split_by_tile_pair}


def validate_analysis(analysis, backend_name: str = "reference"
                      ) -> ValidationReport:
    """Run the operational checks for every channel of ``analysis``;
    returns the evidence, raises `ValidationError` on any contradiction.

    ``backend_name`` picks the executing registry backend: ``"reference"``
    (numpy trace replay) or ``"pallas"`` (the same traces run through VMEM
    ring kernels, interpret-mode off-TPU) — both implement
    ``run(trace) -> peak`` and raise `OrderViolation` identically, so the
    positive AND negative directions hold on either.

    Uses whatever stages ran: verdicts come from the shared classifier,
    slot counts from `.size()` when present (else the pow2 capacities the
    stage would produce), lowerings from `.plan()` records when present
    (else the verdict table).  Plan slot checks are skipped for
    ``topology="pipeline"`` plans — tick capacities bound a self-timed
    execution, not the program-order replay."""
    ppn = analysis.ppn
    ctx = analysis.ctx
    clf = ctx.classifier(ppn)
    sizing = ctx.sizing(ppn)
    patterns = (dict(analysis.patterns) if analysis.patterns is not None
                else _classify_channels(ppn, classifier=clf))
    plan_by_name = ({p.name: p for p in analysis.plans}
                    if analysis.plans is not None else {})
    sizes = dict(analysis.sizes) if analysis.sizes is not None else None
    ref = backend(backend_name)

    report = ValidationReport(ppn.kernel_name, backend_name)
    failures: List[str] = []
    for ch in ppn.channels:
        verdict = patterns[ch.name]
        plan = plan_by_name.get(ch.name)
        lowering = (plan.lowering if plan is not None
                    else lowering_for_pattern(verdict))
        capacity = _channel_capacity(ppn, ch, context=sizing)
        slots = (sizes[ch.name] if sizes is not None
                 else pow2_size(capacity))
        trace = trace_channel(ppn, ch, sizing)
        parts = 1
        late_parts = {ch.name: trace.late_edges}
        # -- positive: the planned implementation must execute the trace
        try:
            if plan is not None and plan.split:
                peak, late_parts = _replay_split_parts(ref, ppn, ch, plan,
                                                       sizing, failures)
                parts = len(plan.parts)
            else:
                peak = ref.implementation(lowering).run(trace)
        except SimulationError as e:
            failures.append(f"{ch.name}: verdict {verdict.value!r} does not "
                            f"execute on {lowering!r}: {e.detail}")
            peak = -1
        # -- occupancy: replay peak == exact capacity, <= planned slots
        if peak >= 0 and (plan is None or not plan.split):
            if peak != capacity:
                failures.append(
                    f"{ch.name}: replay peak occupancy {peak} != sizing "
                    f"capacity {capacity} — the two sweeps disagree")
            if peak > slots:
                failures.append(f"{ch.name}: peak occupancy {peak} exceeds "
                                f"the {slots} planned slots")
        # -- negative: cheaper implementations must REJECT the trace
        rejected = _negative_checks(ref, trace, verdict, failures)
        report.channels.append(ChannelValidation(
            ch.name, verdict.value, lowering, parts, max(peak, 0), capacity,
            slots, rejected, sum(late_parts.values()), late_parts))
    if failures:
        raise ValidationError(ppn.kernel_name, failures)
    return report


def _replay_split_parts(ref, ppn: PPN, ch: Channel, plan, sizing,
                        failures: List[str]) -> Tuple[int, Dict[str, int]]:
    """A split plan executes as one FIFO per recovered part: regenerate the
    parts with the plan's splitter and replay each on a strict queue,
    checking the per-part slot counts from the plan record.  Returns the
    total peak and the per-part late-edge counts — the regenerated parts'
    non-serializable edge sets used to be computed here and dropped; now
    they ride into the report so the selftimed engine exempts the same
    edges at part granularity."""
    parts = _SPLITTERS[plan.lowering](ppn, ch)
    slots_by_depth = {depth: size for depth, _, size in plan.parts}
    if sorted(slots_by_depth) != sorted(p.depth for p in parts):
        failures.append(f"{ch.name}: split regeneration produced parts "
                        f"{sorted(p.depth for p in parts)} but the plan "
                        f"recorded {sorted(slots_by_depth)}")
        return -1, {ch.name: trace_channel(ppn, ch, sizing).late_edges}
    fifo = ref.implementation(FIFO_STREAM)
    total = 0
    late_parts: Dict[str, int] = {}
    for part in parts:
        trace = trace_channel(ppn, part, sizing)
        late_parts[part.name] = trace.late_edges
        peak = fifo.run(trace)
        cap = _channel_capacity(ppn, part, context=sizing)
        if peak != cap:
            failures.append(f"{part.name}: part replay peak {peak} != "
                            f"sizing capacity {cap}")
        if plan.topology == "sequential" and peak > slots_by_depth[part.depth]:
            failures.append(f"{part.name}: part peak {peak} exceeds its "
                            f"{slots_by_depth[part.depth]} planned slots")
        total += peak
    return total, late_parts


def _negative_checks(ref, trace, verdict: Pattern,
                     failures: List[str]) -> Tuple[str, ...]:
    """A non-FIFO verdict must fail on the FIFO queue; a non-in-order verdict
    must also fail on the broadcast register.  Success on a cheaper
    implementation means the classifier over-approximated."""
    if verdict is Pattern.FIFO or trace.num_edges == 0:
        return ()
    rejected: List[str] = []
    expect_reject = [FIFO_STREAM]
    if verdict in (Pattern.OOO, Pattern.OOO_UNICITY):
        expect_reject.append(BROADCAST_REGISTER)
    for lowering in expect_reject:
        try:
            ref.implementation(lowering).run(trace)
        except OrderViolation:
            rejected.append(lowering)
        else:
            failures.append(
                f"{trace.channel}: verdict {verdict.value!r} but the trace "
                f"executes cleanly on {lowering!r} — classifier "
                f"over-approximation")
    return tuple(rejected)
