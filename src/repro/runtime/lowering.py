"""The channel-lowering IR: one vocabulary, one verdict table, one registry.

`Analysis.plan()` emits backend-neutral `ChannelPlan` records whose
``lowering`` field is drawn from the vocabulary below.  Everything that turns
a classification verdict into an implementation goes through this module:

* :data:`PATTERN_LOWERING` — THE verdict → lowering table.  The planner, the
  comm backend and the docs all read it from here; nothing else may encode
  the mapping.
* :class:`ChannelLowering` — the interface a backend implements per lowering.
* :class:`Backend` / :func:`backend` — the registry.  Four backends ship:
  ``"reference"`` (the trace-driven simulator, `runtime/simulator.py`),
  ``"jax"`` (the collective lowerings, `runtime/jax_backend.py`),
  ``"pallas"`` (VMEM-idiom kernels, `runtime/pallas_backend.py`) and
  ``"selftimed"`` (per-event queue machines + the dataflow-driven engine,
  `runtime/selftimed/`); all are loaded lazily on first lookup so importing
  the analysis core never pulls in jax.  A backend may additionally attach
  a whole-PPN ``compile`` hook (the pallas and selftimed backends do —
  `Analysis.compile(backend=...)`).

This module deliberately imports nothing from `repro.core`: the table is
keyed on the classifier's pattern *values* (the `Pattern` enum is str-valued)
so `core/analysis.py` can import it without a cycle.
"""
from __future__ import annotations

import importlib
from typing import Callable, Dict, Iterator, Optional, Tuple

# ------------------------------------------------------------- vocabulary --
# Lowering names, cheapest first.  These strings ARE the IR: they appear in
# `ChannelPlan.lowering`, in `AnalysisReport` JSON, and as registry keys.

FIFO_STREAM = "ppermute"                      # FIFO neighbor stream
DEPTH_SPLIT = "ppermute(depth-split)"         # paper SPLIT, all parts FIFO
CHUNK_SPLIT = "ppermute(chunk-split)"         # per-tile-pair split succeeded
BROADCAST_REGISTER = "ppermute+register"      # in-order, multicast consumer
REORDER_BUFFER = "reorder-buffer"             # out-of-order; addressable

LOWERINGS: Tuple[str, ...] = (FIFO_STREAM, DEPTH_SPLIT, CHUNK_SPLIT,
                              BROADCAST_REGISTER, REORDER_BUFFER)

#: lowerings that stream values in production order (a recovered-FIFO split
#: part is still a stream; the registry treats the split variants as
#: FIFO_STREAM applied per part)
STREAM_LOWERINGS: Tuple[str, ...] = (FIFO_STREAM, DEPTH_SPLIT, CHUNK_SPLIT)

# THE verdict → lowering table (single source of truth).  Keys are
# `repro.core.patterns.Pattern` values.
PATTERN_LOWERING: Dict[str, str] = {
    "fifo": FIFO_STREAM,
    "in-order+mult": BROADCAST_REGISTER,
    "out-of-order+unicity": REORDER_BUFFER,
    "out-of-order": REORDER_BUFFER,
}


def lowering_for_pattern(pattern) -> str:
    """Lowering a channel with this verdict gets when it is not split.
    Accepts a `Pattern` or its string value."""
    return PATTERN_LOWERING[getattr(pattern, "value", pattern)]


def split_lowering(label: str) -> str:
    """Lowering name of a successful split recovery (``label`` is the
    splitter tag: ``"depth-split"`` or ``"chunk-split"``)."""
    name = f"ppermute({label})"
    if name not in LOWERINGS:
        raise KeyError(f"unknown split label {label!r}")
    return name


def is_stream(lowering: str) -> bool:
    return lowering in STREAM_LOWERINGS


def is_cheap(lowering: str) -> bool:
    """True for every lowering served by a neighbor stream (the broadcast
    register rides the same link); only the addressable reorder buffer —
    the lowering the paper's algorithm exists to avoid — is expensive."""
    return lowering != REORDER_BUFFER


#: THE degradation ladder (single source of truth, like PATTERN_LOWERING but
#: for the runtime direction): when a guard observes a cheap lowering's
#: ordering contract violated live, this is the lowering it hot-swaps to.
#: Every cheap entry degrades straight to the addressable reorder buffer —
#: the one lowering whose semantics need no ordering assumption — and the
#: reorder buffer has nowhere further to fall (absent from the table).
DEGRADED_LOWERING: Dict[str, str] = {
    FIFO_STREAM: REORDER_BUFFER,
    DEPTH_SPLIT: REORDER_BUFFER,
    CHUNK_SPLIT: REORDER_BUFFER,
    BROADCAST_REGISTER: REORDER_BUFFER,
}


def degrade(lowering: str) -> str:
    """The lowering a runtime guard falls back to when ``lowering``'s
    ordering contract is violated; raises `KeyError` for lowerings that are
    already fully addressable (nothing weaker to assume)."""
    try:
        return DEGRADED_LOWERING[lowering]
    except KeyError:
        raise KeyError(f"lowering {lowering!r} has no degraded form — it "
                       f"already makes no ordering assumption") from None


# --------------------------------------------------------------- interface --

class ChannelLowering:
    """One lowering's implementation in one backend.

    Subclasses declare which vocabulary entries they implement via the
    registry decorator; what "implement" means is backend-specific —
    the reference backend replays traces (`run(trace) -> peak occupancy`,
    raising on a semantics violation), the jax backend builds collective
    step functions (`step(h, axis, stage, n) -> h_next`).
    """

    #: primary lowering name (set by `Backend.register`)
    lowering: str = ""

    def describe(self) -> str:
        return f"{type(self).__name__}[{self.lowering}]"


class BackendUnavailable(ImportError):
    """A lazily-registered backend's module failed to import.  Carries the
    backend name so a missing optional dependency fails loudly as "backend
    X is unavailable" instead of a bare `ModuleNotFoundError` three imports
    deep."""

    def __init__(self, name: str, module: str, reason: BaseException):
        super().__init__(
            f"backend {name!r} is unavailable: importing {module!r} failed "
            f"({type(reason).__name__}: {reason})")
        self.backend = name
        self.module = module
        self.reason = reason


class Backend:
    """A named set of `ChannelLowering` implementations, one per vocabulary
    entry.  Instances live in the module-level registry (`backend()`).

    A backend may also attach a whole-PPN compiler via :attr:`compile` —
    a callable ``compile(analysis, **options) -> executable`` that turns a
    planned `Analysis` into runnable kernels (`Analysis.compile` resolves
    through this hook)."""

    def __init__(self, name: str):
        self.name = name
        self._impl: Dict[str, Callable[[], ChannelLowering]] = {}
        self.compile: Optional[Callable] = None

    def register(self, *lowerings: str):
        """Class decorator: register ``cls`` as this backend's implementation
        of each named lowering."""
        unknown = [l for l in lowerings if l not in LOWERINGS]
        if unknown:
            raise KeyError(f"unknown lowering(s) {unknown} — the vocabulary "
                           f"is {list(LOWERINGS)}")

        def deco(cls):
            for l in lowerings:
                self._impl[l] = cls
            if not getattr(cls, "lowering", ""):
                cls.lowering = lowerings[0]
            return cls

        return deco

    def supports(self, lowering: str) -> bool:
        return lowering in self._impl

    def implementation(self, lowering: str) -> ChannelLowering:
        """Instantiate this backend's implementation of ``lowering``."""
        try:
            cls = self._impl[lowering]
        except KeyError:
            raise KeyError(
                f"backend {self.name!r} implements no lowering "
                f"{lowering!r} (has: {sorted(self._impl)})") from None
        inst = cls()
        inst.lowering = lowering
        return inst

    def __iter__(self) -> Iterator[str]:
        return iter(sorted(self._impl))


_REGISTRY: Dict[str, Backend] = {}

#: backends resolved on first use — keeps `import repro.core` jax-free
_LAZY_BACKENDS: Dict[str, str] = {
    "reference": "repro.runtime.simulator",
    "jax": "repro.runtime.jax_backend",
    "pallas": "repro.runtime.pallas_backend",
    "selftimed": "repro.runtime.selftimed",
}


def register_backend(name: str) -> Backend:
    """The backend named ``name``, created empty if absent (idempotent —
    backend modules call this at import time to attach implementations)."""
    if name not in _REGISTRY:
        _REGISTRY[name] = Backend(name)
    return _REGISTRY[name]


def backend(name: str) -> Backend:
    """Look up a backend, importing its module on first use.  A lazy module
    that fails to import raises `BackendUnavailable` naming the backend."""
    got = _REGISTRY.get(name)
    if got is not None and got._impl:
        return got
    module = _LAZY_BACKENDS.get(name)
    if module is not None:
        try:
            importlib.import_module(module)
        except Exception as e:                # pragma: no cover - env-specific
            raise BackendUnavailable(name, module, e) from e
    got = _REGISTRY.get(name)
    if got is None:
        raise KeyError(f"no backend {name!r} "
                       f"(known: {sorted(set(_REGISTRY) | set(_LAZY_BACKENDS))})")
    return got


def backend_names() -> Tuple[str, ...]:
    return tuple(sorted(set(_REGISTRY) | set(_LAZY_BACKENDS)))


def available_backends() -> Dict[str, str]:
    """Import every registered backend and report availability: name →
    ``"ok"`` or the reason it cannot load.  Surfaced in
    ``python -m benchmarks.run --smoke`` so a broken lazy import fails
    loudly with the backend's name, not a bare traceback on first use."""
    out: Dict[str, str] = {}
    for name in backend_names():
        try:
            b = backend(name)
            n = sum(1 for _ in b)
            extra = "+compile" if b.compile is not None else ""
            out[name] = f"ok ({n} lowerings{extra})"
        except BackendUnavailable as e:
            out[name] = f"unavailable: {e.reason!r}"
        except Exception as e:                # pragma: no cover - defensive
            out[name] = f"broken: {type(e).__name__}: {e}"
    return out
