"""The artifact of one guarded (fault-injected or clean) execution.

A `ResilienceReport` accounts for the whole detect → recover → degrade
ladder of a run:

* ``injected`` — every fault the plan actually triggered;
* ``detections`` — each violated contract, with the guard mechanism that
  caught it and the culprit channel/process;
* ``recoveries`` — bounded replays/suppressions/restarts that restored the
  fault-free behavior;
* ``swaps`` — FIFO→reorder-buffer hot-swaps (degraded but still correct),
  with the slot cost of giving up the stream discipline;
* ``spills`` — capacity-exhausted channels spilled to unbounded, with the
  planned-vs-effective accounting;
* ``unrecovered`` — faults the guards could only *name*, never silently
  absorb (budget exhausted, snapshot window passed, watchdog spent);
* ``undetected`` — injected faults no guard observed (a validation failure:
  the matrix in `resilience.validate` fails the run on any).

``status`` collapses the ladder: ``clean`` → ``recovered`` → ``degraded``
→ ``unrecovered``.  The report serializes into `AnalysisReport` (schema
v4, ``"resilience"`` field) and renders in the selftimed CLI.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

#: report statuses, best first — a run's status is its worst event
STATUSES = ("clean", "recovered", "degraded", "unrecovered")


@dataclass
class ResilienceReport:
    """Detection/recovery/degradation account of one guarded execution."""

    kernel: str
    policy: str
    plan: Dict[str, Any]                      # FaultPlan.as_dict()
    injected: List[Dict[str, Any]] = field(default_factory=list)
    detections: List[Dict[str, Any]] = field(default_factory=list)
    recoveries: List[Dict[str, Any]] = field(default_factory=list)
    swaps: List[Dict[str, Any]] = field(default_factory=list)
    spills: List[Dict[str, Any]] = field(default_factory=list)
    unrecovered: List[Dict[str, Any]] = field(default_factory=list)
    undetected: List[Dict[str, Any]] = field(default_factory=list)
    watchdog: Dict[str, Any] = field(default_factory=dict)
    completed: bool = False
    #: guard observations made (pops+pushes tagged) — the denominator for
    #: overhead accounting in bench_faults
    guard_events: int = 0
    #: delivered-output streams equal to the fault-free oracle's (None when
    #: no oracle run was available for comparison)
    outputs_match: Optional[bool] = None

    @property
    def status(self) -> str:
        if self.unrecovered or self.undetected or not self.completed:
            return "unrecovered"
        if self.outputs_match is False:
            return "unrecovered"      # silent corruption is the worst case
        if self.swaps or self.spills:
            return "degraded"
        if self.recoveries or self.detections:
            return "recovered"
        return "clean"

    @property
    def detected_all(self) -> bool:
        return not self.undetected

    def as_dict(self) -> Dict[str, Any]:
        return {
            "kernel": self.kernel, "policy": self.policy,
            "status": self.status, "plan": self.plan,
            "injected": list(self.injected),
            "detections": list(self.detections),
            "recoveries": list(self.recoveries),
            "swaps": list(self.swaps), "spills": list(self.spills),
            "unrecovered": list(self.unrecovered),
            "undetected": list(self.undetected),
            "watchdog": dict(self.watchdog),
            "completed": self.completed,
            "guard_events": self.guard_events,
            "outputs_match": self.outputs_match,
            "counts": {"injected": len(self.injected),
                       "detected": len(self.detections),
                       "recovered": len(self.recoveries),
                       "swapped": len(self.swaps),
                       "spilled": len(self.spills),
                       "unrecovered": len(self.unrecovered),
                       "undetected": len(self.undetected)},
        }

    def summary(self) -> str:
        w = self.watchdog or {}
        return (f"{self.kernel} [{self.policy}] resilience: {self.status} — "
                f"{len(self.injected)} injected, "
                f"{len(self.detections)} detected, "
                f"{len(self.recoveries)} recovered, "
                f"{len(self.swaps)} swapped, {len(self.spills)} spilled, "
                f"{len(self.unrecovered)} unrecovered "
                f"(watchdog {w.get('ticks', 0)}/{w.get('limit', 0)} ticks)")

    def render(self) -> str:
        out = [self.summary()]
        if self.injected:
            out.append("  injected:")
            out += [f"    {e['spec']}" for e in self.injected]
        if self.detections:
            out.append("  detected:")
            out += [f"    {e['violation']:12s} on {e['target']} "
                    f"via {e['mechanism']}" for e in self.detections]
        if self.recoveries:
            out.append("  recovered:")
            out += [f"    {e['action']:12s} on {e['target']} "
                    f"(attempt {e['attempts']})" for e in self.recoveries]
        for e in self.swaps:
            out.append(f"  hot-swap: {e['channel']} {e['from']} -> "
                       f"{e['to']} (stream slots {e['stream_slots']}, "
                       f"addressable high-water {e['addressable_slots']})")
        for e in self.spills:
            out.append(f"  spill: {e['channel']} capacity "
                       f"{e['capacity']} -> unbounded "
                       f"(planned {e['planned']}, occupancy "
                       f"{e['occupancy']})")
        for e in self.unrecovered:
            out.append(f"  UNRECOVERED: {e['violation']} on {e['target']} "
                       f"— {e['detail']}")
        for e in self.undetected:
            out.append(f"  UNDETECTED: {e['spec']}")
        return "\n".join(out)
