"""`Analysis.validate(mode="faults")` — the fault matrix.

For one analyzed kernel this stage proves, operationally, the resilience
contract the guards claim:

* **no false positives** — a guarded fault-free run must come back
  ``clean`` (guards armed on every channel, zero detections) and its
  delivered-payload streams become the oracle;
* **engine matrix** — for representative targets (a stream-lowered
  channel, a broadcast-register channel, an addressable channel, a
  producing actor) each applicable fault kind is injected into a guarded
  self-timed execution; every fault must be **detected**, and the run must
  either **recover/degrade with outputs equal to the oracle** or come back
  **unrecovered with the culprit named** — never a silent wrong answer,
  never a hang (the watchdog bounds recovery, the engine detects deadlock
  structurally);
* **trace matrix** — the same token faults injected at the wire level
  (`faulted_trace`) must be rejected by the guarded channel
  implementations (`guarded_replay`: order discipline + multiset audit) on
  the reference backend — and identically on the pallas VMEM-ring backend
  when requested.

The evidence is a `ResilienceValidation` (embedded in `AnalysisReport`
under ``"resilience"``, schema v4); contradictions raise the shared
`runtime.validate.ValidationError`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

from ..lowering import (BROADCAST_REGISTER, REORDER_BUFFER, STREAM_LOWERINGS,
                        lowering_for_pattern)
from ..simulator import trace_channel
from ..validate import ValidationError
from ..selftimed.validate import executable_capacities
from .faults import (CAPACITY, CORRUPT, CRASH, DROP, DUPLICATE, REORDER,
                     STALL, Fault, FaultPlan, expected_pop_counts,
                     faulted_trace)
from .guards import GuardViolation, guarded_replay, mode_for_lowering
from .harness import run_guarded

#: engine-level kinds exercised per guard mode of the target channel
ENGINE_KINDS = {"fifo": (DROP, DUPLICATE, REORDER, CORRUPT, CAPACITY),
                "register": (REORDER, CORRUPT),
                "reorder": (DROP, CORRUPT)}

#: trace-level kinds that violate each guard mode's contract (an
#: addressable buffer legally serves any pop order, so only conservation
#: faults are detectable there — and at trace level a corrupt is a
#: misaddressed pop, which conservation does catch)
TRACE_KINDS = {"fifo": (DROP, DUPLICATE, REORDER, CORRUPT),
               "register": (DROP, DUPLICATE, REORDER),
               "reorder": (DROP, DUPLICATE, CORRUPT)}


@dataclass
class ResilienceValidation:
    """The fault-matrix evidence (embedded in `AnalysisReport`)."""

    kernel: str
    clean: Dict[str, Any]              # oracle run: status + summary
    matrix: List[Dict[str, Any]] = field(default_factory=list)
    trace_matrix: List[Dict[str, Any]] = field(default_factory=list)
    trace_backends: List[str] = field(default_factory=list)

    @property
    def injected(self) -> int:
        return len(self.matrix) + len(self.trace_matrix)

    @property
    def recovered(self) -> int:
        return sum(1 for r in self.matrix
                   if r["status"] in ("recovered", "degraded"))

    def as_dict(self) -> Dict[str, Any]:
        return {"mode": "faults", "kernel": self.kernel,
                "clean": dict(self.clean),
                "matrix": list(self.matrix),
                "trace_matrix": list(self.trace_matrix),
                "trace_backends": list(self.trace_backends),
                "counts": {"injected": self.injected,
                           "engine_cases": len(self.matrix),
                           "trace_cases": len(self.trace_matrix),
                           "recovered": self.recovered}}

    def summary(self) -> str:
        unrec = sum(1 for r in self.matrix if r["status"] == "unrecovered")
        return (f"{self.kernel}: fault matrix green — {len(self.matrix)} "
                f"engine faults ({self.recovered} recovered/degraded, "
                f"{unrec} unrecovered-but-named), "
                f"{len(self.trace_matrix)} wire faults rejected on "
                f"{'/'.join(self.trace_backends)}")


def channel_lowerings(analysis) -> Dict[str, str]:
    """Channel name → lowering, from `.plan()` records when present, else
    the verdict table over (possibly cached) classifications."""
    if analysis.plans is not None:
        return {p.name: p.lowering for p in analysis.plans}
    pats = analysis.patterns
    if pats is None:
        clf = analysis.ctx.classifier(analysis.ppn)
        pats = {ch.name: clf.classify(ch) for ch in analysis.ppn.channels}
    return {name: lowering_for_pattern(p) for name, p in pats.items()}


def _pick_targets(analysis, lowerings: Dict[str, str]) -> Dict[str, Any]:
    """Representative fault targets: the first channel of each guard mode
    with at least 3 tokens, plus the stream producer (an actor that owes
    tokens downstream, so its stall/crash is observable)."""
    ppn = analysis.ppn
    values = {c.name: c for c in ppn.channels}
    picked: Dict[str, Any] = {"channels": {}, "process": None}
    szctx = analysis.ctx.sizing(ppn)
    for ch in ppn.channels:
        if ch.num_edges < 3:
            continue
        low = lowerings.get(ch.name, REORDER_BUFFER)
        mode = mode_for_lowering(low)
        if mode in picked["channels"]:
            continue
        tr = trace_channel(ppn, ch, szctx)
        if tr.num_values < 3:
            continue
        picked["channels"][mode] = {"name": ch.name, "lowering": low,
                                    "values": tr.num_values}
        if picked["process"] is None:
            prod = values[ch.name].producer
            fires = len(ppn.processes[prod].pts)
            if fires >= 3:
                picked["process"] = {"name": prod, "fires": fires}
    if picked["process"] is None:
        for p in ppn.processes.values():
            if len(p.pts) >= 3:
                picked["process"] = {"name": p.name, "fires": len(p.pts)}
                break
    return picked


def faults_validate(analysis, policy: str = "sequential",
                    trace_backends: Sequence[str] = ("reference",),
                    ) -> ResilienceValidation:
    """Run the fault matrix for ``analysis``; returns the evidence, raises
    `ValidationError` on any contradiction."""
    ppn = analysis.ppn
    caps = executable_capacities(analysis)
    lows = channel_lowerings(analysis)
    failures: List[str] = []

    # -- no false positives: a guarded clean run must be clean
    oracle = run_guarded(ppn, caps, FaultPlan(), lows, policy=policy)
    if oracle.resilience.status != "clean":
        raise ValidationError(ppn.kernel_name, [
            f"guards raised on a fault-free run (false positive): "
            f"{oracle.resilience.summary()}"])
    if not oracle.run.completed:
        raise ValidationError(ppn.kernel_name, [
            "guarded fault-free run did not complete"])

    targets = _pick_targets(analysis, lows)
    matrix: List[Dict[str, Any]] = []

    # -- engine matrix: inject into live guarded executions
    for mode, tgt in sorted(targets["channels"].items()):
        name, nv = tgt["name"], tgt["values"]
        at = min(1, nv - 1)
        for kind in ENGINE_KINDS[mode]:
            arg = 0 if kind == CAPACITY else (3 if kind == CORRUPT else None)
            # size the replay log to the stream so recovery is in reach —
            # the bounded-window give-up path is covered by test_resilience
            plan = FaultPlan(faults=(Fault(kind, name, at, arg=arg),),
                             snapshot_window=nv)
            row = _engine_case(ppn, caps, lows, plan, policy, oracle,
                               f"{kind}:{name}@{at}", failures)
            row.update({"layer": "engine", "mode": mode})
            matrix.append(row)
    if targets["process"] is not None:
        pname = targets["process"]["name"]
        at = min(1, targets["process"]["fires"] - 1)
        for kind in (STALL, CRASH):
            plan = FaultPlan(faults=(Fault(kind, pname, at, span=3),))
            row = _engine_case(ppn, caps, lows, plan, policy, oracle,
                               f"{kind}:{pname}@{at}", failures)
            row.update({"layer": "engine", "mode": "process"})
            matrix.append(row)

    # -- trace matrix: wire-level faults must be rejected in replay
    trace_matrix: List[Dict[str, Any]] = []
    szctx = analysis.ctx.sizing(ppn)
    chan_by_name = {c.name: c for c in ppn.channels}
    for backend_name in trace_backends:
        for mode, tgt in sorted(targets["channels"].items()):
            name, nv = tgt["name"], tgt["values"]
            trace = trace_channel(ppn, chan_by_name[name], szctx)
            expected = expected_pop_counts(trace)
            for kind in TRACE_KINDS[mode]:
                fault = Fault(kind, name, min(1, nv - 1),
                              arg=3 if kind == CORRUPT else None)
                bad = faulted_trace(trace, fault)
                row = {"layer": "trace", "backend": backend_name,
                       "mode": mode, "fault": fault.spec()}
                try:
                    guarded_replay(bad, tgt["lowering"], backend_name,
                                   expected=expected)
                    failures.append(
                        f"{name}: wire fault {fault.spec()} replayed "
                        f"cleanly on {backend_name}:{tgt['lowering']} — "
                        f"undetected")
                    row["detected"] = False
                except GuardViolation as e:
                    row["detected"] = True
                    row["violation"] = e.violation
                    row["mechanism"] = e.mechanism
                    if e.channel != name:
                        failures.append(
                            f"{name}: wire fault {fault.spec()} detected "
                            f"but blamed on {e.channel!r}")
                trace_matrix.append(row)

    if failures:
        raise ValidationError(ppn.kernel_name, failures)
    return ResilienceValidation(
        kernel=ppn.kernel_name,
        clean={"status": oracle.resilience.status,
               "guard_events": oracle.resilience.guard_events,
               "summary": oracle.resilience.summary()},
        matrix=matrix, trace_matrix=trace_matrix,
        trace_backends=list(trace_backends))


def _engine_case(ppn, caps, lows, plan: FaultPlan, policy: str, oracle,
                 label: str, failures: List[str]) -> Dict[str, Any]:
    """One engine-level fault case: inject, then hold the run to the
    contract — detected, and recovered-with-oracle-outputs or
    unrecovered-with-named-culprit."""
    gr = run_guarded(ppn, caps, plan, lows, policy=policy, oracle=oracle)
    r = gr.resilience
    f = plan.faults[0]
    row: Dict[str, Any] = {
        "fault": f.spec(), "status": r.status,
        "detected": bool(r.detections), "injected": bool(r.injected),
        "recoveries": len(r.recoveries), "swaps": len(r.swaps),
        "spills": len(r.spills), "outputs_match": r.outputs_match,
        "mechanisms": sorted({d["mechanism"] for d in r.detections}),
    }
    if not r.injected:
        failures.append(f"{label}: fault never triggered — bad matrix "
                        f"target")
        return row
    if r.undetected:
        failures.append(f"{label}: injected but NO guard detected it")
        return row
    if not r.detections:
        failures.append(f"{label}: no detection recorded")
        return row
    if r.status in ("recovered", "degraded"):
        if not r.completed:
            failures.append(f"{label}: status {r.status} but the run did "
                            f"not complete")
        if r.outputs_match is not True:
            failures.append(f"{label}: status {r.status} but delivered "
                            f"outputs differ from the fault-free oracle — "
                            f"silent corruption")
    elif r.status == "unrecovered":
        named = {e["target"] for e in r.unrecovered} | \
                {d["target"] for d in r.detections}
        if f.target not in named:
            failures.append(f"{label}: unrecovered but the culprit "
                            f"{f.target!r} is not named (named: "
                            f"{sorted(named)})")
    else:
        failures.append(f"{label}: fault injected yet the run reports "
                        f"{r.status!r}")
    return row
