"""Runtime guards: detect live what the classifier proved statically.

The paper's verdicts license cheap lowerings by *proving* order properties
of the schedule.  These guards check the same properties at runtime, so a
violated assumption (a fault, a mis-planned capacity, a buggy transport)
is **detected** — never a silent wrong answer:

* **sequence tags** — every token carries its wire position; a FIFO-lowered
  channel's consumer checks each pop is the next tag (gap / out-of-order /
  duplicate all show), a broadcast register checks tags never regress, an
  addressable buffer checks payload integrity and, at completion, pop
  completeness;
* **multiset audit** (`audit_trace`) — trace-level completeness: the popped
  multiset must equal the expected per-value multiplicities (catches drops
  and duplicates that an order discipline alone tolerates, e.g. a skipped
  head under the pallas ring's ``v <= last_p`` check);
* **progress watchdog** (`ProgressWatchdog`) — bounds quiesce
  interventions so recovery never becomes a hang, and distinguishes
  fault-induced stall (an actor refusing work — observable as
  `ProcessStats.denials`) from genuine structural deadlock (the engine's
  wait-for cycle).

`guarded_replay` is the trace-level entry: replay a (possibly faulted)
trace through a backend's channel implementation *and* the multiset audit,
mapping every failure to a `GuardViolation` naming the culprit channel.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from ..lowering import (BROADCAST_REGISTER, REORDER_BUFFER, STREAM_LOWERINGS,
                        backend)
from ..simulator import ChannelTrace, SimulationError
from .faults import expected_pop_counts

#: guard discipline per lowering: what ordering property the tags check
GUARD_MODES: Dict[str, str] = dict(
    {low: "fifo" for low in STREAM_LOWERINGS},
    **{BROADCAST_REGISTER: "register", REORDER_BUFFER: "reorder"})


def mode_for_lowering(lowering: str) -> str:
    """``"fifo"`` | ``"register"`` | ``"reorder"`` — the tag discipline a
    channel with this lowering is guarded by."""
    return GUARD_MODES.get(lowering, "reorder")


class GuardViolation(RuntimeError):
    """A runtime guard detected a violated channel contract.

    ``channel`` names the culprit, ``violation`` is the detected condition
    (``gap`` | ``duplicate`` | ``out-of-order`` | ``corrupt``), and
    ``mechanism`` names the guard that caught it."""

    def __init__(self, channel: str, violation: str, mechanism: str,
                 detail: str):
        super().__init__(f"{channel}: {violation} ({mechanism}): {detail}")
        self.channel = channel
        self.violation = violation
        self.mechanism = mechanism
        self.detail = detail


def audit_trace(trace: ChannelTrace,
                expected: np.ndarray) -> Optional[GuardViolation]:
    """Multiset audit: compare the trace's popped multiset against the
    expected per-value multiplicities; returns the violation (None if
    clean).  This is the completeness half of the guard — order disciplines
    check *sequence*, this checks *conservation*."""
    got = (np.bincount(trace.pops, minlength=trace.num_values)
           if trace.num_edges else np.zeros(trace.num_values, np.int64))
    if len(got) > len(expected):      # a pop named a nonexistent position
        return GuardViolation(
            trace.channel, "corrupt", "multiset-audit",
            f"pop of push position {int(len(got) - 1)} beyond the "
            f"{len(expected)} values ever pushed")
    missing = np.flatnonzero(got < expected)
    if len(missing):
        m = int(missing[0])
        return GuardViolation(
            trace.channel, "gap", "multiset-audit",
            f"value at push position {m} popped {int(got[m])} of the "
            f"expected {int(expected[m])} times")
    extra = np.flatnonzero(got > expected)
    if len(extra):
        e = int(extra[0])
        return GuardViolation(
            trace.channel, "duplicate", "multiset-audit",
            f"value at push position {e} popped {int(got[e])} times, "
            f"expected {int(expected[e])}")
    return None


def guarded_replay(trace: ChannelTrace, lowering: str,
                   backend_name: str = "reference",
                   expected: Optional[np.ndarray] = None,
                   **impl_kw) -> int:
    """Replay ``trace`` through ``backend_name``'s implementation of
    ``lowering`` with the guards armed: the implementation's own order
    discipline plus the multiset audit (against ``expected`` pop counts —
    pass the unfaulted trace's `expected_pop_counts`; defaults to this
    trace's own, which makes the audit a no-op for self-consistent traces).

    Returns the implementation's peak occupancy; raises `GuardViolation`
    naming the culprit channel on any detected violation."""
    exp = expected if expected is not None else expected_pop_counts(trace)
    impl = backend(backend_name).implementation(lowering)
    try:
        peak = impl.run(trace, **impl_kw)
    except SimulationError as e:
        detail = e.detail if hasattr(e, "detail") else str(e)
        violation = ("duplicate" if "popped" in detail and "times" in detail
                     else "gap" if "gap" in detail or "empty slot" in detail
                     else "out-of-order")
        raise GuardViolation(trace.channel, violation,
                             f"{backend_name}:{lowering}", detail) from e
    bad = audit_trace(trace, exp)
    if bad is not None:
        raise bad
    return peak


class ProgressWatchdog:
    """Bounds the guards' quiesce interventions (never a hang) and keeps the
    stall-vs-deadlock ledger.

    Each time the engine quiesces with work pending the hooks call
    `tick()`; once the budget is spent the watchdog answers ``False`` and
    the engine falls through to its structural deadlock report — so a
    recovery loop that makes no progress terminates in bounded time, by
    construction rather than by timeout.  `restart()` separately budgets
    crashed-actor restarts (`FaultPlan.max_restarts`)."""

    def __init__(self, limit: int, max_restarts: int):
        self.limit = limit
        self.max_restarts = max_restarts
        self.ticks = 0
        self.restarts = 0
        self.exhausted = False

    def tick(self) -> bool:
        """One quiesce intervention; False once the budget is spent."""
        self.ticks += 1
        if self.ticks > self.limit:
            self.exhausted = True
            return False
        return True

    def restart(self) -> bool:
        """One crashed-actor restart; False once the budget is spent."""
        if self.restarts >= self.max_restarts:
            return False
        self.restarts += 1
        return True

    def as_dict(self) -> Dict[str, int]:
        return {"ticks": self.ticks, "limit": self.limit,
                "restarts": self.restarts,
                "max_restarts": self.max_restarts,
                "exhausted": self.exhausted}
