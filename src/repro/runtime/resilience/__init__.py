"""Fault injection and self-healing channel guards for the PPN runtime.

The paper's static verdicts license cheap channel lowerings; this package
checks the licensed properties *live* and keeps a faulted network
producing correct answers — or failing loudly with a named culprit:

* `faults` — declarative, seeded `FaultPlan` (token drop / duplicate /
  reorder / corruption, actor stall / crash, capacity loss) triggered at
  chosen fire-counts, plus trace-level injection (`faulted_trace`);
* `guards` — sequence-tag disciplines per lowering, the multiset audit,
  `guarded_replay`, and the `ProgressWatchdog` that bounds recovery;
* `harness` — `ResilienceHooks` (an `EngineHooks` implementation) and
  `run_guarded`, wiring injection + detection + bounded recovery +
  FIFO→reorder-buffer degradation into the self-timed engine;
* `report` — the `ResilienceReport` artifact (schema-v4 ``"resilience"``
  field of `AnalysisReport`);
* `validate` — the per-kernel fault matrix behind
  ``Analysis.validate(mode="faults")``.
"""
from .faults import (ALL_KINDS, CAPACITY, CHANNEL_KINDS, CORRUPT, CRASH,
                     DROP, DUPLICATE, PROCESS_KINDS, REORDER, STALL,
                     TOKEN_KINDS, Fault, FaultPlan, FaultSpecError,
                     expected_pop_counts, faulted_trace, parse_fault)
from .guards import (GUARD_MODES, GuardViolation, ProgressWatchdog,
                     audit_trace, guarded_replay, mode_for_lowering)
from .harness import GuardedRun, ResilienceHooks, run_guarded
from .report import STATUSES, ResilienceReport
from .validate import (ResilienceValidation, channel_lowerings,
                       faults_validate)

__all__ = [
    "ALL_KINDS", "CAPACITY", "CHANNEL_KINDS", "CORRUPT", "CRASH", "DROP",
    "DUPLICATE", "PROCESS_KINDS", "REORDER", "STALL", "TOKEN_KINDS",
    "Fault", "FaultPlan", "FaultSpecError", "expected_pop_counts",
    "faulted_trace", "parse_fault",
    "GUARD_MODES", "GuardViolation", "ProgressWatchdog", "audit_trace",
    "guarded_replay", "mode_for_lowering",
    "GuardedRun", "ResilienceHooks", "run_guarded",
    "STATUSES", "ResilienceReport",
    "ResilienceValidation", "channel_lowerings", "faults_validate",
]
