"""Guarded self-timed execution: inject faults, detect, recover, degrade.

`ResilienceHooks` plugs into the engine's `EngineHooks` seam and plays both
sides of the game in one deterministic pass:

* the **injector** applies the `FaultPlan` at exact wire positions / fire
  counts (drop, duplicate, reorder, corrupt, stall, crash, capacity loss);
* the **guards** tag every token with its wire position plus a checksum of
  the payload, verify the channel's ordering discipline at every pop, and
  keep a bounded per-channel replay log (`FaultPlan.snapshot_window`);
* **recovery** follows the ladder: suppress a duplicate at the push site,
  replay a corrupted/lost token from the snapshot (bounded by
  ``max_replays``, the `train.ft.retrying` idiom), wait out a stalled actor
  / restart a crashed one (bounded by ``max_restarts``), hot-swap a
  violated FIFO to the addressable reorder buffer
  (`lowering.DEGRADED_LOWERING`) and keep executing, spill an exhausted
  channel to unbounded with accounting;
* the **watchdog** bounds quiesce interventions (`watchdog_limit`) so a
  recovery loop that stops making progress terminates as a *named*
  unrecovered report — never a hang, never a timeout.

Every event lands in a `ResilienceReport`; `run_guarded` is the one entry
point and also produces the delivered-payload streams (pop order per
channel) that `resilience.validate` compares against a fault-free oracle —
the "no silent wrong answer" check.
"""
from __future__ import annotations

from collections import deque
from typing import Dict, List, Mapping, Optional, Tuple

from ..lowering import DEGRADED_LOWERING, REORDER_BUFFER
from ..selftimed.engine import EngineHooks, SelfTimedEngine
from ..selftimed.observe import SelfTimedReport
from .faults import (CAPACITY, CORRUPT, DROP, DUPLICATE, REORDER, STALL,
                     Fault, FaultPlan)
from .guards import ProgressWatchdog, mode_for_lowering
from .report import ResilienceReport


class _ChanGuard:
    """Per-channel guard + injector state (engine channel index scoped)."""

    __slots__ = ("name", "lowering", "mode", "next_seq", "expect",
                 "pending_swap", "tag", "checksum", "payload", "delivered",
                 "snapshot", "replays", "writer_pos", "faults")

    def __init__(self, name: str, lowering: str, window: int):
        self.name = name
        self.lowering = lowering
        self.mode = mode_for_lowering(lowering)
        self.next_seq = 0
        self.expect = 0                 # next tag (fifo) / front tag (reg)
        self.pending_swap: Optional[int] = None
        self.tag: Dict[int, int] = {}
        self.checksum: Dict[int, int] = {}   # v -> true payload (side-band)
        self.payload: Dict[int, int] = {}    # v -> payload as on the wire
        self.delivered: List[int] = []       # payloads served, pop order
        self.snapshot: deque = deque(maxlen=max(1, window))  # replay log
        self.replays = 0
        self.writer_pos: Dict[int, int] = {}
        self.faults: List[List] = []    # [Fault, triggered?] pairs


class ResilienceHooks(EngineHooks):
    """Fault injector + runtime guards over the engine hook seam.

    ``lowerings`` maps channel name → lowering (absent channels are guarded
    addressably); ``recover=False`` detects and reports but never replays
    or suppresses — the detect-only mode bench_faults uses to price
    detection alone."""

    def __init__(self, plan: Optional[FaultPlan] = None,
                 lowerings: Optional[Mapping[str, str]] = None,
                 recover: bool = True):
        self.plan = plan or FaultPlan()
        self.lowerings = dict(lowerings or {})
        self.recover = recover
        self.watchdog = ProgressWatchdog(self.plan.watchdog_limit,
                                         self.plan.max_restarts)
        self.guard_events = 0
        self.injected: List[Dict] = []
        self.detections: List[Dict] = []
        self.recoveries: List[Dict] = []
        self.swaps: List[Dict] = []
        self.spills: List[Dict] = []
        self.unrecovered: List[Dict] = []
        self._detected_targets: set = set()
        self._capacity_planned: Dict[int, Optional[int]] = {}
        self._failed_tokens: set = set()     # (ci, v) already given up on

    # -------------------------------------------------------------- bind --

    def bind(self, engine: SelfTimedEngine) -> None:
        self.engine = engine
        w = self.plan.snapshot_window
        self.chan = [
            _ChanGuard(c.name, self.lowerings.get(c.name, REORDER_BUFFER), w)
            for c in engine.chans]
        for ci, c in enumerate(engine.chans):
            self._capacity_planned[ci] = c.capacity
            for f in self.plan.for_channel(c.name):
                self.chan[ci].faults.append([f, False])
        self._writer_pos_built = False   # built lazily, first quiesce
        self.pstate: Dict[int, Dict] = {}
        pidx = {p.name: i for i, p in enumerate(engine.procs)}
        for f in self.plan.faults:
            if f.on_process and f.target in pidx:
                self.pstate[pidx[f.target]] = {
                    "fault": f, "active": False, "expired": False,
                    "resume_fires": None, "waits": 0}
        # fault-free plans take the deferred-verification fast path: the
        # engine records the wire (per-channel push/pop value order, one
        # list append per token) and `finalize` checks the sequence-tag
        # discipline in one batched pass — same math as the inline guards
        # at a fraction of the cost (bench_faults' <10% overhead budget)
        self.deferred = not self.plan.faults
        self.inline_wire = not self.deferred
        self.gates_fires = bool(self.pstate)
        if self.deferred:
            self.push_chan_log: List[List[int]] = [[] for _ in engine.chans]
            self.pop_chan_log: List[List[int]] = [[] for _ in engine.chans]

    def _ensure_writer_pos(self, engine: SelfTimedEngine) -> None:
        """Producer write positions per value: the observable "has the
        producer advanced past this token's send?" gap test at quiesce.
        O(total tokens), so built only when a quiesce actually happens."""
        if self._writer_pos_built:
            return
        self._writer_pos_built = True
        for pi in range(len(engine.procs)):
            for k, outs in enumerate(engine.outputs[pi]):
                for ci, v in outs:
                    self.chan[ci].writer_pos[v] = int(engine.pos[pi][k])

    # ----------------------------------------------------------- records --

    def _detect(self, target: str, violation: str, mechanism: str,
                detail: str) -> None:
        self.detections.append({"target": target, "violation": violation,
                                "mechanism": mechanism, "detail": detail})
        self._detected_targets.add(target)

    def _recover(self, target: str, action: str, attempts: int) -> None:
        self.recoveries.append({"target": target, "action": action,
                                "attempts": attempts})

    def _fail(self, target: str, violation: str, detail: str) -> None:
        self.unrecovered.append({"target": target, "violation": violation,
                                 "detail": detail})

    # ---------------------------------------------------------- injector --

    def _trigger(self, ci: int, seq: int) -> Optional[Fault]:
        for rec in self.chan[ci].faults:
            if not rec[1] and rec[0].at == seq:
                rec[1] = True
                self.injected.append(rec[0].as_dict())
                return rec[0]
        return None

    # ------------------------------------------------------------- hooks --

    def fire_allowed(self, engine: SelfTimedEngine, pi: int) -> bool:
        st = self.pstate.get(pi)
        if st is None or st["expired"]:
            return True
        f = st["fault"]
        if engine.pstats[pi].fires < f.at:
            return True
        if not st["active"]:
            st["active"] = True
            st["resume_fires"] = engine.fires + f.span
            self.injected.append(f.as_dict())
        if f.kind == STALL and engine.fires >= st["resume_fires"]:
            st["expired"] = True
            self._detect(f.target, "actor-stall", "progress-watchdog",
                         f"{st['waits'] or f.span} denial(s) observed, "
                         f"resumed after the wait elapsed")
            self._recover(f.target, "waited", 1)
            return True
        return False

    def on_push(self, engine: SelfTimedEngine, pi: int, ci: int, v: int):
        st = self.chan[ci]
        self.guard_events += 1
        seq = st.next_seq
        st.next_seq = seq + 1
        if st.pending_swap is not None:
            tag, st.pending_swap = st.pending_swap, None
        else:
            tag = seq
        payload = seq                   # true content == wire position
        ops = None                      # None -> plain single delivery
        f = self._trigger(ci, seq)
        if f is not None:
            if f.kind == DROP:
                ops = ()
            elif f.kind == DUPLICATE:
                # a second wire copy of the same tag arrives; the push-site
                # tag check sees the repeat immediately
                self._detect(st.name, "duplicate", "sequence-tag",
                             f"wire tag {tag} pushed twice")
                if self.recover:
                    self._recover(st.name, "suppress", 1)
                else:
                    ops = ((v, "deliver"), (v, "phantom"))
            elif f.kind == REORDER:
                st.pending_swap = tag   # next token takes this wire slot
                tag = tag + 1           # this one lands a slot late
            elif f.kind == CORRUPT:
                payload = seq + (f.arg if f.arg else 1)
            elif f.kind == CAPACITY:
                c = engine.chans[ci]
                c.capacity = f.arg if f.arg is not None else 0
        st.tag[v] = tag
        st.payload[v] = payload
        st.checksum[v] = seq            # guard side-band: per-token checksum
        st.snapshot.append(v)           # bounded replay log (maxlen evicts)
        return ops

    def on_pop(self, engine: SelfTimedEngine, pi: int, ci: int,
               v: int) -> None:
        st = self.chan[ci]
        self.guard_events += 1
        tag = st.tag.get(v)
        payload = st.payload.get(v, -1)
        if tag is None:                 # never pushed — engine can't serve
            st.delivered.append(payload)
            return
        if st.mode == "fifo":
            if tag != st.expect:
                self._detect(
                    st.name, "out-of-order", "sequence-tag",
                    f"pop saw wire tag {tag}, expected {st.expect}")
                self._hot_swap(engine, ci)
            else:
                st.expect = tag + 1
        elif st.mode == "register":
            if tag < st.expect:
                self._detect(
                    st.name, "out-of-order", "sequence-tag",
                    f"register regressed to wire tag {tag} after "
                    f"advancing to {st.expect}")
                self._hot_swap(engine, ci)
            else:
                st.expect = tag
        served = payload
        truth = st.checksum[v]
        if payload != truth:
            self._detect(st.name, "corrupt", "checksum",
                         f"token {v} payload {payload} fails its "
                         f"checksum ({truth})")
            if (self.recover and v in st.snapshot
                    and st.replays < self.plan.max_replays):
                st.replays += 1
                served = truth          # replayed from the snapshot log
                self._recover(st.name, "replay", st.replays)
            else:
                self._fail(st.name, "corrupt",
                           "snapshot window passed or replay budget "
                           "exhausted — corrupted payload served")
        st.delivered.append(served)

    def on_quiesce(self, engine: SelfTimedEngine,
                   reasons: Mapping[int, Tuple[str, int, int]]) -> str:
        if not self.watchdog.tick():
            self._fail("watchdog", "no-progress",
                       f"intervention budget ({self.watchdog.limit}) "
                       f"exhausted with work pending")
            return "deadlock"
        acted = False
        # stalled / crashed actors: virtual time passes while the network
        # is idle; a crash needs (and consumes) a restart grant
        for pi, st in self.pstate.items():
            if not st["active"] or st["expired"]:
                continue
            if engine.pc[pi] >= engine.n_inst[pi]:
                continue
            f = st["fault"]
            if f.kind == STALL:
                st["waits"] += 1
                if st["waits"] >= f.span:
                    st["expired"] = True
                    self._detect(f.target, "actor-stall",
                                 "progress-watchdog",
                                 f"{engine.pstats[pi].denials} denial(s) "
                                 f"observed; wait of {f.span} elapsed")
                    self._recover(f.target, "waited", st["waits"])
                acted = True
            elif not st.get("abandoned"):       # CRASH
                self._detect(f.target, "actor-crash", "progress-watchdog",
                             f"{engine.pstats[pi].denials} denial(s), no "
                             f"progress while work pending")
                if self.recover and self.watchdog.restart():
                    st["expired"] = True
                    self._recover(f.target, "restart",
                                  self.watchdog.restarts)
                    acted = True
                else:
                    st["abandoned"] = True
                    self._fail(f.target, "actor-crash",
                               "restart budget exhausted — culprit actor "
                               "named, run abandoned")
        # starved consumers: a token whose producer already advanced past
        # its send was lost in flight — replay it from the snapshot log
        self._ensure_writer_pos(engine)
        for pi, (kind, ci, v) in sorted(reasons.items()):
            if kind != "empty":
                continue
            st = self.chan[ci]
            c = engine.chans[ci]
            wp = st.writer_pos.get(v)
            if wp is None or engine.pc[c.producer] <= wp:
                continue                # producer genuinely hasn't sent it
            if c.pushed_step[v] >= 0:
                continue                # visible already; not a gap
            if (ci, v) in self._failed_tokens:
                continue                # already reported unrecoverable
            self._detect(st.name, "gap", "progress-watchdog",
                         f"token {v} lost in flight (producer advanced "
                         f"past its send, consumer starving)")
            if (self.recover and v in st.snapshot
                    and st.replays < self.plan.max_replays):
                st.replays += 1
                engine.redeliver(ci, v)
                self._recover(st.name, "replay", st.replays)
                acted = True
            else:
                self._failed_tokens.add((ci, v))
                self._fail(st.name, "gap",
                           "snapshot window passed or replay budget "
                           "exhausted — token unrecoverable")
        # capacity exhaustion: spill the blocking full channel(s) to
        # unbounded, with planned-vs-effective accounting
        for pi, (kind, ci, v) in sorted(reasons.items()):
            if kind != "full":
                continue
            c = engine.chans[ci]
            if c.capacity is None:
                continue
            planned = self._capacity_planned[ci]
            self._detect(c.name, "capacity-exhausted", "progress-watchdog",
                         f"occupancy {c.occ} blocked at capacity "
                         f"{c.capacity} (planned {planned})")
            self.spills.append({"channel": c.name, "capacity": c.capacity,
                                "planned": planned, "occupancy": int(c.occ),
                                "fault_induced": c.capacity != planned})
            c.capacity = None
            acted = True
        return "continue" if acted else "deadlock"

    # -------------------------------------------------------- degradation --

    def _hot_swap(self, engine: SelfTimedEngine, ci: int) -> None:
        st = self.chan[ci]
        if st.mode == "reorder":
            return
        to = DEGRADED_LOWERING.get(st.lowering, REORDER_BUFFER)
        self.swaps.append({"channel": st.name, "from": st.lowering,
                           "to": to,
                           "stream_slots": self._capacity_planned[ci],
                           "addressable_slots": None})   # filled at finalize
        st.mode = "reorder"
        if self.recover:
            self._recover(st.name, "hot-swap", 1)

    # ----------------------------------------------------------- finalize --

    def _verify_deferred(self) -> None:
        """Batched verification of the recorded wire — the deferred
        counterpart of the inline pop-site checks.  A FIFO's pops must
        replay its pushes verbatim (tag ``i`` arriving at pop ``i``); a
        register's tags must never regress.  The common case is one
        C-speed list comparison per channel; the Python work happens only
        on an actual violation."""
        self.guard_events = (sum(map(len, self.push_chan_log))
                             + sum(map(len, self.pop_chan_log)))
        for ci, st in enumerate(self.chan):
            pushes = self.push_chan_log[ci]
            pops = self.pop_chan_log[ci]
            if st.mode == "fifo":
                if pops != pushes[:len(pops)]:
                    bad = next(i for i, (a, b) in enumerate(zip(pops, pushes))
                               if a != b)
                    self._detect(
                        st.name, "out-of-order", "sequence-tag",
                        f"pop saw wire tag {pushes.index(pops[bad])}, "
                        f"expected {bad}")
                    self._hot_swap(self.engine, ci)
            elif st.mode == "register":
                tag = {v: i for i, v in enumerate(pushes)}
                tags = list(map(tag.__getitem__, pops))
                if tags != sorted(tags):
                    bad = next(i for i in range(1, len(tags))
                               if tags[i] < tags[i - 1])
                    self._detect(
                        st.name, "out-of-order", "sequence-tag",
                        f"register regressed to wire tag {tags[bad]} after "
                        f"advancing to {tags[bad - 1]}")
                    self._hot_swap(self.engine, ci)

    def finalize(self, engine: SelfTimedEngine,
                 run: SelfTimedReport) -> ResilienceReport:
        if self.deferred:
            self._verify_deferred()
        # capacity audit: configured capacity must match the plan — catches
        # a capacity fault that never blocked anything
        for ci, c in enumerate(engine.chans):
            planned = self._capacity_planned[ci]
            if c.capacity != planned and c.name not in \
                    {s["channel"] for s in self.spills}:
                self._detect(c.name, "capacity-loss", "capacity-audit",
                             f"configured capacity {c.capacity} != "
                             f"planned {planned}")
        for sw in self.swaps:
            for c in engine.chans:
                if c.name == sw["channel"]:
                    sw["addressable_slots"] = int(c.high)
        # a reorder on an addressable buffer violates nothing — wire order
        # is not part of that channel's contract — so silence there is
        # correctness, not a missed detection
        benign = {(REORDER, st.name) for st in self.chan
                  if st.mode == "reorder"}
        undetected = [f for f in self.injected
                      if f["target"] not in self._detected_targets
                      and (f["kind"], f["target"]) not in benign]
        report = ResilienceReport(
            kernel=engine.ppn.kernel_name, policy=engine.policy,
            plan=self.plan.as_dict(),
            injected=self.injected, detections=self.detections,
            recoveries=self.recoveries, swaps=self.swaps,
            spills=self.spills, unrecovered=self.unrecovered,
            undetected=undetected, watchdog=self.watchdog.as_dict(),
            completed=run.completed, guard_events=self.guard_events)
        return report

    def delivered_streams(self) -> Dict[str, List[int]]:
        if self.deferred:
            # payload == checksum == wire tag when nothing was injected;
            # reconstructed on demand so a plain overhead run never pays
            out: Dict[str, List[int]] = {}
            for ci, st in enumerate(self.chan):
                tag = {v: i for i, v in enumerate(self.push_chan_log[ci])}
                out[st.name] = [tag.get(v, -1)
                                for v in self.pop_chan_log[ci]]
            return out
        return {st.name: list(st.delivered) for st in self.chan}


class GuardedRun:
    """Everything one guarded execution produced.  ``delivered`` (the
    per-channel payload streams in pop order) is materialized lazily —
    only oracle comparisons need it."""

    def __init__(self, run: SelfTimedReport, resilience: ResilienceReport,
                 hooks: ResilienceHooks):
        self.run = run
        self.resilience = resilience
        self._hooks = hooks
        self._delivered: Optional[Dict[str, List[int]]] = None

    @property
    def delivered(self) -> Dict[str, List[int]]:
        if self._delivered is None:
            self._delivered = self._hooks.delivered_streams()
        return self._delivered

    @property
    def status(self) -> str:
        return self.resilience.status


def run_guarded(ppn, capacities: Optional[Mapping[str, Optional[int]]] = None,
                plan: Optional[FaultPlan] = None,
                lowerings: Optional[Mapping[str, str]] = None,
                policy: str = "sequential",
                recover: bool = True,
                oracle: Optional[GuardedRun] = None,
                record_timeline: bool = False) -> GuardedRun:
    """Execute ``ppn`` with the guards armed and ``plan``'s faults injected.

    ``lowerings`` (channel name → lowering) selects each channel's guard
    discipline — pass the analysis plan's lowerings; unknown channels are
    guarded addressably.  When ``oracle`` (a fault-free `GuardedRun`) is
    given, the delivered-payload streams are compared and
    ``resilience.outputs_match`` is set — the no-silent-corruption check.
    Never hangs: structural deadlock is detected by the engine, recovery
    loops are bounded by the plan's watchdog budget."""
    hooks = ResilienceHooks(plan=plan, lowerings=lowerings, recover=recover)
    engine = SelfTimedEngine(ppn, capacities, policy=policy,
                             record_timeline=record_timeline, hooks=hooks)
    run = engine.run()
    resilience = hooks.finalize(engine, run)
    gr = GuardedRun(run=run, resilience=resilience, hooks=hooks)
    if oracle is not None:
        resilience.outputs_match = (run.completed
                                    and gr.delivered == oracle.delivered)
    return gr
