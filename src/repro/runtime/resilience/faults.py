"""Declarative, seeded, reproducible fault plans for the PPN runtime.

A `Fault` is one thing going wrong at one place at one chosen moment:

======== ========== ==================================================
kind     target     meaning
======== ========== ==================================================
drop     channel    the token pushed at wire position ``at`` is lost
                    in flight (the producer advances, the consumer
                    starves on it)
duplicate channel   the token at wire position ``at`` arrives twice —
                    the second copy holds a queue slot but is never a
                    legal head
reorder  channel    the tokens at wire positions ``at`` and ``at+1``
                    swap on the wire (a FIFO's internal order
                    scrambled)
corrupt  channel    the payload of the token at wire position ``at``
                    is corrupted (``arg`` = value delta, default +1)
stall    process    once the actor has fired ``at`` times it refuses
                    work until ``span`` more network fires (or idle
                    watchdog rounds) elapse
crash    process    as stall, but the actor never resumes on its own —
                    only a watchdog restart brings it back
capacity channel    at wire position ``at`` the channel loses slots:
                    its capacity drops to ``arg`` (default 0)
======== ========== ==================================================

Triggers are *fire-counts* (wire position = the producer's push index on
that channel; stall/crash = the actor's own fire count), so a plan is
deterministic and schedule-independent — the same plan replayed against
the same network injects the same faults, whatever the policy.

`FaultPlan` bundles faults with the bounded-recovery budgets the guards
honor (snapshot window, replay attempts, watchdog limit) and a ``seed``
that makes `FaultPlan.random` reproducible.

The same vocabulary injects at the *trace* level: `faulted_trace` rewrites
a `ChannelTrace`'s pop sequence the way the fault would scramble the wire,
for replay through the reference / pallas channel implementations.
"""
from __future__ import annotations

import random as _random
from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..simulator import ChannelTrace

DROP = "drop"
DUPLICATE = "duplicate"
REORDER = "reorder"
CORRUPT = "corrupt"
STALL = "stall"
CRASH = "crash"
CAPACITY = "capacity"

#: faults that target a token on a channel
TOKEN_KINDS: Tuple[str, ...] = (DROP, DUPLICATE, REORDER, CORRUPT)
#: faults that target an actor
PROCESS_KINDS: Tuple[str, ...] = (STALL, CRASH)
#: faults that target a channel (token faults + capacity loss)
CHANNEL_KINDS: Tuple[str, ...] = TOKEN_KINDS + (CAPACITY,)
ALL_KINDS: Tuple[str, ...] = TOKEN_KINDS + PROCESS_KINDS + (CAPACITY,)


class FaultSpecError(ValueError):
    """A fault spec string / plan could not be understood."""


@dataclass(frozen=True)
class Fault:
    """One declaratively scheduled fault (see module docstring)."""

    kind: str
    target: str                   # channel name (channel kinds) or process
    at: int = 0                   # trigger fire-count / wire position
    span: int = 4                 # stall length (network fires or idle rounds)
    arg: Optional[int] = None     # corrupt: payload delta; capacity: new cap

    def __post_init__(self):
        if self.kind not in ALL_KINDS:
            raise FaultSpecError(f"unknown fault kind {self.kind!r} "
                                 f"(one of {', '.join(ALL_KINDS)})")
        if self.at < 0:
            raise FaultSpecError(f"{self.kind}:{self.target}: trigger "
                                 f"@{self.at} must be >= 0")

    @property
    def on_process(self) -> bool:
        return self.kind in PROCESS_KINDS

    def spec(self) -> str:
        s = f"{self.kind}:{self.target}@{self.at}"
        if self.kind in (STALL, CRASH) and self.span != 4:
            s += f"*{self.span}"
        elif self.arg is not None:
            s += f"*{self.arg}"
        return s

    def as_dict(self) -> Dict[str, object]:
        return {"kind": self.kind, "target": self.target, "at": self.at,
                "span": self.span, "arg": self.arg, "spec": self.spec()}


def parse_fault(spec: str) -> Fault:
    """Parse ``KIND:TARGET[@AT][*N]`` — ``N`` is the stall span for
    stall/crash, the payload delta for corrupt, the surviving capacity for
    capacity loss.  Target names may contain anything but ``@`` (channel
    names like ``a->b.x[0]`` are fine)."""
    if ":" not in spec:
        raise FaultSpecError(
            f"bad fault spec {spec!r} — expected KIND:TARGET[@AT][*N], "
            f"e.g. drop:a->b.x[0]@5 or stall:compute@3*8")
    kind, rest = spec.split(":", 1)
    at, n = 0, None
    if "@" in rest:
        rest, trig = rest.rsplit("@", 1)
        if "*" in trig:
            trig, ns = trig.rsplit("*", 1)
            try:
                n = int(ns)
            except ValueError:
                raise FaultSpecError(f"bad *N in fault spec {spec!r}") \
                    from None
        try:
            at = int(trig)
        except ValueError:
            raise FaultSpecError(f"bad @AT in fault spec {spec!r}") from None
    if not rest:
        raise FaultSpecError(f"bad fault spec {spec!r} — empty target")
    kw: Dict[str, int] = {}
    if n is not None:
        if kind in PROCESS_KINDS:
            kw["span"] = n
        else:
            kw["arg"] = n
    return Fault(kind=kind, target=rest, at=at, **kw)


@dataclass(frozen=True)
class FaultPlan:
    """A reproducible set of faults plus the recovery budgets guards honor.

    ``snapshot_window`` — per-channel replay log depth (most recent sends);
    ``max_replays`` — bounded token-replay attempts per channel (the
    `train.ft.retrying` idiom: give up loudly, never retry forever);
    ``max_restarts`` — crashed-actor restarts the watchdog will grant;
    ``watchdog_limit`` — quiesce interventions before the watchdog declares
    the run unrecoverable (the bound that guarantees no hang)."""

    faults: Tuple[Fault, ...] = ()
    seed: int = 0
    snapshot_window: int = 16
    max_replays: int = 4
    max_restarts: int = 1
    watchdog_limit: int = 64

    @classmethod
    def parse(cls, specs: Sequence[str], **kw) -> "FaultPlan":
        return cls(faults=tuple(parse_fault(s) for s in specs), **kw)

    @classmethod
    def single(cls, kind: str, target: str, at: int = 0, **kw) -> "FaultPlan":
        extra = {k: kw.pop(k) for k in ("span", "arg") if k in kw}
        return cls(faults=(Fault(kind, target, at, **extra),), **kw)

    @classmethod
    def random(cls, ppn, seed: int = 0,
               kinds: Sequence[str] = ALL_KINDS) -> "FaultPlan":
        """One random single-fault plan for ``ppn``, deterministic in
        ``seed``: a kind, a live target of the right species, and a trigger
        inside the target's actual activity range."""
        rng = _random.Random(seed)
        chans = []
        for ch in ppn.channels:
            if ch.num_edges == 0:
                continue
            nv = (len(np.unique(ch.src_pts, axis=0))
                  if ch.src_pts.ndim == 2 else ch.num_edges)
            chans.append((ch.name, max(1, int(nv))))
        procs = [(p.name, len(p.pts)) for p in ppn.processes.values()
                 if len(p.pts) > 0]
        kind = rng.choice([k for k in kinds
                           if (procs if k in PROCESS_KINDS else chans)])
        if kind in PROCESS_KINDS:
            name, n = rng.choice(procs)
            at = rng.randrange(n)
            return cls(faults=(Fault(kind, name, at,
                                     span=rng.randrange(1, 5)),), seed=seed)
        name, nv = rng.choice(chans)
        hi = max(1, nv - 1 if kind == REORDER else nv)
        at = rng.randrange(hi)
        arg = rng.randrange(1, 7) if kind == CORRUPT else (
            0 if kind == CAPACITY else None)
        return cls(faults=(Fault(kind, name, at, arg=arg),), seed=seed)

    def for_channel(self, name: str) -> List[Fault]:
        return [f for f in self.faults
                if not f.on_process and f.target == name]

    def for_process(self, name: str) -> List[Fault]:
        return [f for f in self.faults if f.on_process and f.target == name]

    def validate_against(self, channel_names: Sequence[str],
                         process_names: Sequence[str]) -> None:
        """Every fault must name a real target of the right species."""
        cset, pset = set(channel_names), set(process_names)
        for f in self.faults:
            pool = pset if f.on_process else cset
            what = "process" if f.on_process else "channel"
            if f.target not in pool:
                raise FaultSpecError(
                    f"{f.spec()}: no {what} named {f.target!r}")

    def as_dict(self) -> Dict[str, object]:
        return {"faults": [f.as_dict() for f in self.faults],
                "seed": self.seed,
                "snapshot_window": self.snapshot_window,
                "max_replays": self.max_replays,
                "max_restarts": self.max_restarts,
                "watchdog_limit": self.watchdog_limit}


# ------------------------------------------------------- trace-level faults --

def faulted_trace(trace: ChannelTrace, fault: Fault) -> ChannelTrace:
    """Rewrite a channel trace's pop stream the way ``fault`` would scramble
    the wire, keeping the per-edge arrays coherent (pop order is sorted
    consumer rank; the per-edge write ranks are re-derived from the faulted
    pops).  Capacity/process faults have no trace-level form and raise."""
    if fault.kind not in TOKEN_KINDS:
        raise FaultSpecError(f"{fault.kind!r} has no trace-level form "
                             f"(token kinds: {', '.join(TOKEN_KINDS)})")
    if trace.num_edges == 0:
        return trace
    pops = trace.pops.copy()
    r_sorted = np.sort(trace.r_rank, kind="stable")
    at = min(fault.at, len(pops) - 1)
    if fault.kind == DROP:
        # the pop of the token pushed at position `at` never happens
        hit = np.flatnonzero(pops == at)
        keep = np.ones(len(pops), dtype=bool)
        if len(hit):
            keep[hit[0]] = False
        pops, r_sorted = pops[keep], r_sorted[keep]
    elif fault.kind == DUPLICATE:
        hit = np.flatnonzero(pops == at)
        i = int(hit[0]) if len(hit) else at
        pops = np.insert(pops, i + 1, pops[i])
        r_sorted = np.insert(r_sorted, i + 1, r_sorted[i])
    elif fault.kind == REORDER:
        i = min(at, len(pops) - 2)
        if i < 0:
            return trace
        pops[i], pops[i + 1] = pops[i + 1], pops[i]
    else:                             # CORRUPT: a pop reads the wrong slot
        delta = fault.arg if fault.arg else 1
        pops[at] = (pops[at] + delta) % trace.num_values
    order = np.argsort(trace.value_wrank, kind="stable")
    wrank_by_pos = trace.value_wrank[order]
    return replace(trace, num_edges=len(pops), pops=pops,
                   r_rank=r_sorted, w_rank=wrank_by_pos[pops])


#: per-value expected pop multiplicity of an unfaulted trace — the guard's
#: ground truth for the multiset audit (`guards.audit_trace`)
def expected_pop_counts(trace: ChannelTrace) -> np.ndarray:
    return np.bincount(trace.pops, minlength=trace.num_values)
