"""Pallas codegen support: planned PPNs → fused VMEM-ring stencil kernels.

This module holds the *program* side of the ``"pallas"`` backend
(`runtime/pallas_backend.py` holds the per-channel trace-replay
implementations and registers both into the lowering registry).  It
generalizes the hand-written idiom of `repro.kernels.stencil_fifo.kernel`:
a time-tiled band stencil whose iteration space is blocked along one
*streamed* spatial axis, with the dependences crossing the block boundary —
the channels the paper's SPLIT isolates at each depth — carried in a VMEM
scratch ring across the *sequential* Pallas grid.  In-block dependences
never leave VMEM/VREGs; the addressable-buffer fallback round-trips the
whole array per timestep instead (the FPGA FIFO-vs-buffer saving, restated
for the TPU memory hierarchy).

The generated geometry, for a stencil of radius ``r`` along the streamed
axis (items are scalars for jacobi-1d, rows for jacobi-2d, planes for
heat-3d; the skew is ``r`` cells per time step so tile writes stay
block-aligned):

* ring level ``t`` holds the trailing ``2r`` items of the global item
  stream at time level ``t`` — block ``j`` deposits them, block ``j+1``
  consumes them;
* the ring has ``steps + 1`` levels; levels are addressed modulo
  ``ring_depth`` (default ``steps + 1``), so an *undersized* ring is a real
  ring-capacity failure (level ``t`` is clobbered before the next block
  reads it), not an index error — `tests/test_pallas.py` injects exactly
  that;
* blocks need ``r·steps ≡ 0 (mod block)`` so the skewed final row is
  block-aligned; ``r·steps / block`` extra flush blocks drain the tail.
  ``block = 1`` (the degenerate 1×…×1 tiling) is supported: the trailing
  halo then accumulates across several predecessor blocks.

`compile_analysis` is the `Analysis.compile(backend="pallas")` entry point:
it reads the `.plan()` records, picks the VMEM-ring mode iff every planned
lowering is a stream/register (`is_cheap`), and binds the kernel's
*semantics* from the `STENCIL_PROGRAMS` table (the polyhedral spec carries
dataflow, not arithmetic — the update function is the one ingredient the
analysis cannot derive).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .lowering import is_cheap


def default_interpret() -> bool:
    """True off-TPU: generated kernels run (and are CI-tested) through the
    Pallas interpreter; on a TPU host they compile for real."""
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------- programs --

@dataclass(frozen=True)
class StencilProgram:
    """The semantic half of a band-stencil kernel: what one time step
    computes.  ``update`` receives ``2·radius + 1`` arrays — the previous
    time level shifted by ``-radius … +radius`` along the streamed axis,
    each of shape ``(block,) + inner`` — and returns the new level.  Inner
    (non-streamed) axes are full-width; their boundary handling lives
    inside ``update`` (Dirichlet-zero, matching the `ref` oracle)."""

    name: str                                  # registry kernel it mirrors
    radius: int                                # dependence radius, streamed axis
    inner_rank: int                            # rank of one streamed item
    update: Callable[..., jnp.ndarray]
    ref: Callable[[jnp.ndarray, int], jnp.ndarray]   # pure-jnp oracle
    notes: str = ""


def _shift_inner(a: jnp.ndarray, axis: int, off: int) -> jnp.ndarray:
    """``a`` shifted by ``off`` along ``axis`` with Dirichlet-zero fill
    (jnp.pad-free: concatenation lowers cleanly in Pallas)."""
    if off == 0:
        return a
    pad_shape = list(a.shape)
    pad_shape[axis] = abs(off)
    zeros = jnp.zeros(pad_shape, a.dtype)
    if off > 0:      # neighbor at index - off
        body = jax.lax.slice_in_dim(a, 0, a.shape[axis] - off, axis=axis)
        return jnp.concatenate([zeros, body], axis=axis)
    body = jax.lax.slice_in_dim(a, -off, a.shape[axis], axis=axis)
    return jnp.concatenate([body, zeros], axis=axis)


def _jacobi1d_update(left, center, right):
    return (left + center + right) / 3.0


def _jacobi2d_update(up, center, down):
    jl = _shift_inner(center, -1, +1)
    jr = _shift_inner(center, -1, -1)
    return (center + jl + jr + up + down) / 5.0


def _heat3d_update(up, center, down):
    jl = _shift_inner(center, -2, +1)
    jr = _shift_inner(center, -2, -1)
    kl = _shift_inner(center, -1, +1)
    kr = _shift_inner(center, -1, -1)
    return (center
            + 0.125 * (up - 2.0 * center + down)
            + 0.125 * (jl - 2.0 * center + jr)
            + 0.125 * (kl - 2.0 * center + kr))


def _lazy_ref(module: str, fn: str):
    def call(a0, steps):
        import importlib
        return getattr(importlib.import_module(module), fn)(a0, steps)
    return call


#: kernel-registry name → band-stencil semantics.  The analysis plans the
#: channels; this table supplies the arithmetic the PPN does not carry.
STENCIL_PROGRAMS: Dict[str, StencilProgram] = {
    "jacobi-1d": StencilProgram(
        "jacobi-1d", radius=1, inner_rank=0, update=_jacobi1d_update,
        ref=_lazy_ref("repro.kernels.stencil_fifo.ref", "jacobi_1d"),
        notes="3-point average; items are cells (paper Fig. 1/3)"),
    "jacobi-2d": StencilProgram(
        "jacobi-2d", radius=1, inner_rank=1, update=_jacobi2d_update,
        ref=_lazy_ref("repro.kernels.stencil_bands.ref", "jacobi_2d"),
        notes="5-point average; items are rows, j streams inside"),
    "heat-3d": StencilProgram(
        "heat-3d", radius=1, inner_rank=2, update=_heat3d_update,
        ref=_lazy_ref("repro.kernels.stencil_bands.ref", "heat_3d"),
        notes="7-point star; items are planes, (j,k) stream inside"),
}


# -------------------------------------------------------- fused ring kernel --

def _ring_kernel(x_ref, o_ref, ring_old, ring_new, *, block: int, steps: int,
                 nblocks: int, radius: int, halo: int, ring_depth: int,
                 n_items: int, inner: Tuple[int, ...], update: Callable):
    """One grid step = one block of the streamed axis; the FIFO ring carries
    each time level's trailing ``halo`` items to the next block."""
    j = pl.program_id(0)

    # left of the domain is Dirichlet-zero: initialize the ring at block 0
    @pl.when(j == 0)
    def _init():
        ring_old[...] = jnp.zeros_like(ring_old)

    # this block's t=0 items; flush blocks (j >= nblocks) are all-zero
    row = jnp.where(j < nblocks, x_ref[...], jnp.zeros_like(x_ref[...]))

    # item index of row position s at time level t is  j·block − r·t + s
    ids = jax.lax.broadcasted_iota(jnp.int32, (block, 1), 0)
    ids = ids.reshape((block,) + (1,) * len(inner))

    # depth-0 ring level: trailing halo of the global stream at t=0 (for
    # block < halo the trailing window spans predecessors — accumulate)
    ring_new[0] = jnp.concatenate([ring_old[0], row], axis=0)[-halo:]

    def time_step(t, row):
        left = ring_old[(t - 1) % ring_depth]          # (halo,) + inner
        prev_full = jnp.concatenate([left, row], axis=0)
        if halo < 2 * radius:     # injected narrow halo: the missing items
            gone = jnp.zeros((2 * radius - halo,) + inner, row.dtype)
            prev_full = jnp.concatenate([gone, prev_full], axis=0)  # are GONE
        new_row = update(*[jax.lax.slice_in_dim(prev_full, k, k + block,
                                                axis=0)
                           for k in range(2 * radius + 1)])
        idx = j * block - radius * t + ids
        new_row = jnp.where((idx >= 0) & (idx < n_items), new_row, 0.0)
        ring_new[t % ring_depth] = jnp.concatenate(
            [ring_old[t % ring_depth], new_row], axis=0)[-halo:]
        return new_row

    row = jax.lax.fori_loop(1, steps + 1, time_step, row, unroll=False)

    # block j's final row covers items [(j − flush)·block, …); early blocks
    # write a dummy block 0 that block `flush` overwrites
    o_ref[...] = row

    # publish this block's ring levels for the next grid step
    ring_old[...] = ring_new[...]


def _addressable_step(x: jnp.ndarray, *, radius: int, update: Callable,
                      interpret: bool) -> jnp.ndarray:
    """One time step as its own pallas_call over the WHOLE array — the
    addressable-buffer fallback: every step writes the full level back to
    HBM and reads it again (the paper's reorder-buffer cost model)."""

    def kernel(x_ref, o_ref):
        a = x_ref[...]
        shifts = [_shift_inner(a, 0, radius - k) for k in range(2 * radius + 1)]
        o_ref[...] = update(*shifts)

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=interpret,
    )(x)


@dataclass
class CompiledStencil:
    """The executable `Analysis.compile(backend="pallas")` returns.

    ``mode`` is ``"fifo-ring"`` (fused kernel, channels in VMEM scratch) or
    ``"addressable"`` (per-timestep HBM round-trip — the fallback a
    reorder-buffer plan forces).  ``ring_depth`` / ``halo`` exist for the
    negative direction: compiling with fewer ring levels than ``steps + 1``
    (or a narrower halo than ``2·radius``) produces a kernel whose output
    provably diverges from the oracle — an undersized ring *fails*, it does
    not degrade gracefully.
    """

    program: StencilProgram
    mode: str
    plans: Tuple = ()
    kernel_name: str = ""
    interpret: Optional[bool] = None
    diagnostics: Dict[str, object] = field(default_factory=dict)

    def ring_slots(self, steps: int) -> int:
        """Items held in one ring buffer: (steps+1) levels × 2r per level
        (each item is one channel value of inner shape)."""
        return (steps + 1) * 2 * self.program.radius

    def __call__(self, x: jnp.ndarray, steps: int, block: int,
                 interpret: Optional[bool] = None,
                 ring_depth: Optional[int] = None,
                 halo: Optional[int] = None) -> jnp.ndarray:
        interpret = (default_interpret() if interpret is None
                     else interpret) if self.interpret is None else (
                         self.interpret if interpret is None else interpret)
        p = self.program
        x = x.astype(jnp.float32)
        if self.mode == "addressable":
            step = functools.partial(_addressable_step, radius=p.radius,
                                     update=p.update, interpret=interpret)
            a = x
            for _ in range(steps):      # deliberately NOT fused: one kernel
                a = step(a)             # launch + full-array round trip per t
            return a
        n_items = x.shape[0]
        inner = x.shape[1:]
        if len(inner) != p.inner_rank:
            raise ValueError(f"{p.name}: expected rank {p.inner_rank + 1} "
                             f"input, got shape {x.shape}")
        if n_items % block:
            raise ValueError(f"n_items {n_items} % block {block} != 0")
        if (p.radius * steps) % block:
            raise ValueError(f"radius·steps ({p.radius * steps}) must be a "
                             f"multiple of block ({block}) so skewed writes "
                             f"stay block-aligned")
        nblocks = n_items // block
        flush = (p.radius * steps) // block
        depth = steps + 1 if ring_depth is None else ring_depth
        h = 2 * p.radius if halo is None else halo
        blk = (block,) + inner

        out = pl.pallas_call(
            functools.partial(
                _ring_kernel, block=block, steps=steps, nblocks=nblocks,
                radius=p.radius, halo=h, ring_depth=depth, n_items=n_items,
                inner=inner, update=p.update),
            grid=(nblocks + flush,),
            in_specs=[pl.BlockSpec(
                blk, lambda j: (jnp.minimum(j, nblocks - 1),)
                + (0,) * len(inner))],
            out_specs=pl.BlockSpec(
                blk, lambda j: (jnp.maximum(j - flush, 0),)
                + (0,) * len(inner)),
            out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
            scratch_shapes=[
                pltpu.VMEM((depth, h) + inner, jnp.float32),  # ring (read)
                pltpu.VMEM((depth, h) + inner, jnp.float32),  # ring (write)
            ],
            interpret=interpret,
        )(x)
        return out

    def describe(self) -> str:
        return (f"CompiledStencil[{self.program.name}] mode={self.mode} "
                f"radius={self.program.radius} "
                f"plans={len(self.plans)} ({self.diagnostics})")


def _memory_channels(analysis) -> frozenset:
    """Names of channels touching a load/store (memory) process.  In the
    generated kernel these are served by `BlockSpec` index maps — HBM DMA,
    addressable by nature — so their verdicts never force the addressable
    *compute* mode; only compute↔compute channels decide ring vs. buffer."""
    mem = lambda p: p.startswith(("load", "store"))
    return frozenset(ch.name for ch in analysis.ppn.channels
                     if mem(ch.producer) or mem(ch.consumer))


def compile_analysis(analysis, mode: Optional[str] = None,
                     interpret: Optional[bool] = None) -> CompiledStencil:
    """The pallas backend's `Backend.compile` hook.

    Requires a `.plan()` stage: the ChannelPlan records decide the mode —
    the fused VMEM-ring kernel iff every compute↔compute lowering is served
    by a stream/register (`is_cheap`; load/store-process channels map to
    `BlockSpec` DMA and are exempt), else the addressable per-timestep
    fallback.  ``mode`` forces one (the benchmark measures both)."""
    if analysis.plans is None:
        raise ValueError("compile() needs the .plan() stage: run "
                         "analyze(...).classify().fifoize().size().plan() "
                         "first — the ChannelPlan records ARE the input")
    name = analysis.ppn.kernel_name
    program = STENCIL_PROGRAMS.get(name)
    if program is None:
        raise KeyError(
            f"no pallas stencil program for kernel {name!r} "
            f"(have: {sorted(STENCIL_PROGRAMS)}) — the PPN carries dataflow, "
            f"not arithmetic; register the update in STENCIL_PROGRAMS")
    memory = _memory_channels(analysis)
    compute_plans = [p for p in analysis.plans if p.name not in memory]
    cheap = all(p.is_cheap for p in compute_plans)
    expensive = [p.name for p in compute_plans if not p.is_cheap]
    if mode is None:
        mode = "fifo-ring" if cheap else "addressable"
    if mode == "fifo-ring" and not cheap:
        raise ValueError(
            f"{name}: cannot compile the VMEM-ring kernel — plan(s) "
            f"{expensive} need the addressable reorder buffer (run "
            f".fifoize() first, or compile mode='addressable')")
    if mode not in ("fifo-ring", "addressable"):
        raise ValueError(f"unknown mode {mode!r}")
    return CompiledStencil(
        program=program, mode=mode, plans=tuple(analysis.plans),
        kernel_name=name, interpret=interpret,
        diagnostics={"cheap_plans": sum(p.is_cheap for p in compute_plans),
                     "compute_plans": len(compute_plans),
                     "memory_plans": len(memory),
                     "reorder_plans": expensive})
