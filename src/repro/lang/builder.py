"""Declarative loop-nest builder: author kernels, compile to `Kernel`.

The polyhedral pipeline (PPN construction → tiling → FIFO recovery → sizing)
consumes affine kernel specs; hand-assembling them means raw `Statement` /
`Constraint` tuples, hand-numbered 2d+1 schedule constants, and copy-pasted
load/store boilerplate.  `Nest` replaces all of that:

    from repro.lang import Nest

    k = Nest("gemm")
    C, A, B = k.array("C", N, N), k.array("A", N, N), k.array("B", N, N)
    k.inputs(C, A, B)               # load_* boundary processes (prologue)
    k.outputs(C)                    # store_* boundary processes (epilogue)
    with k.loop("i", 0, N) as i, k.loop("j", 0, N) as j:
        k.stmt("init", writes=[C[i, j]], reads=[C[i, j]])
        with k.loop("k", 0, N) as kk:
            k.stmt("upd", writes=[C[i, j]],
                   reads=[C[i, j], A[i, kk], B[kk, j]])
    k.tile("upd", some_tiling)      # per-statement tiling attachment
    report = analyze(k).classify().fifoize().size().report()

* **Index expressions** are operator-overloaded affine arithmetic over loop
  iterators (`A[i, kk]`, `a[t - 1, i + 1]`, `B[2 * i + 1]`).  A non-affine
  product (`A[i * j]`) degrades to a poison value the validation pass reports
  with the offending statement — never a mid-expression numpy error.
* **Schedules** are assigned automatically from program order: the loop tree
  yields the classic 2d+1 timestamp (position constants interleaved with the
  loop counters), so there is nothing to hand-number and nothing to collide —
  unless positions are pinned explicitly with ``at=`` (for composing
  fragments), which the validation pass cross-checks.
* **Boundary processes** are derived from the declared I/O: `inputs()`
  arrays get a ``load_<name>`` process in the prologue phase, `outputs()`
  arrays a ``store_<name>`` process in the epilogue phase, with domains from
  the declared array shapes and schedules from
  `repro.core.schedule.boundary_schedule` (prologue ≪ body ≪ epilogue under
  ANY tiling — the phase constant leads the timestamp).  When `inputs()` is
  not called, arrays whose first access in program order is a read are
  loaded, in first-read order.
* **Validation** (`validate()` collects, `build()` raises `SpecError`)
  rejects malformed specs with diagnostics naming the offending statement:
  non-affine accesses, out-of-scope iterators, schedule collisions, empty or
  unbounded iteration domains, arity mismatches, unknown tiling targets,
  mismatched tiling widths, duplicate statement names.

`case()` packages the compiled kernel as a `KernelCase`; `__kernelcase__()`
is the protocol `analyze()` / `sweep()` / the kernel registry use to accept
builder programs directly.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

from ..core.affine import Constraint, LinExpr, ge, lt, v
from ..core.dataflow import Access, Kernel, Statement
from ..core.polyhedron import Polyhedron
from ..core.registry import KernelCase
from ..core.schedule import (AffineSchedule, PROLOGUE_C0, boundary_schedule,
                             epilogue_c0)
from ..core.tiling import Tiling


class SpecError(ValueError):
    """A kernel spec failed validation; ``diagnostics`` lists every problem
    found (each naming the offending statement or loop)."""

    def __init__(self, diagnostics: Sequence[str]):
        self.diagnostics = list(diagnostics)
        super().__init__("invalid kernel spec:\n  "
                         + "\n  ".join(self.diagnostics))


class NonAffine:
    """Poison value produced by non-affine arithmetic (e.g. ``i * j``): it
    absorbs further arithmetic so expression building never raises; the
    validation pass reports it with the statement that used it."""

    __slots__ = ("reason",)

    def __init__(self, reason: str):
        self.reason = reason

    def _absorb(self, *_args) -> "NonAffine":
        return self

    __add__ = __radd__ = __sub__ = __rsub__ = _absorb
    __mul__ = __rmul__ = __neg__ = _absorb

    def __repr__(self) -> str:
        return f"<non-affine: {self.reason}>"


def _coerce(x) -> Union[LinExpr, NonAffine]:
    """Affine coercion that degrades to poison instead of raising."""
    if isinstance(x, NonAffine):
        return x
    if isinstance(x, bool) or isinstance(x, float):
        if isinstance(x, float) and x.is_integer():
            return LinExpr.const_expr(int(x))
        return NonAffine(f"{x!r} is not an integer")
    try:
        return LinExpr.coerce(x)
    except TypeError:
        return NonAffine(f"{x!r} is not an affine expression")


class AffExpr(LinExpr):
    """`LinExpr` with closed operator overloading for the builder: affine
    combinations stay `AffExpr`; products of two non-constant expressions
    (and non-integer operands) degrade to :class:`NonAffine` poison."""

    __slots__ = ()

    @staticmethod
    def _of(e: LinExpr) -> "AffExpr":
        out = AffExpr.__new__(AffExpr)
        out.coeffs = e.coeffs
        out.const = e.const
        return out

    @staticmethod
    def var(name: str, coeff: int = 1) -> "AffExpr":
        return AffExpr._of(LinExpr.var(name, coeff))

    def __add__(self, other):
        other = _coerce(other)
        if isinstance(other, NonAffine):
            return other
        return AffExpr._of(LinExpr.__add__(self, other))

    __radd__ = __add__

    def __neg__(self) -> "AffExpr":
        return AffExpr._of(LinExpr.__neg__(self))

    def __sub__(self, other):
        other = _coerce(other)
        if isinstance(other, NonAffine):
            return other
        return self + (-other)

    def __rsub__(self, other):
        other = _coerce(other)
        if isinstance(other, NonAffine):
            return other
        return AffExpr._of(other) + (-self)

    def __mul__(self, k):
        if isinstance(k, NonAffine):
            return k
        if isinstance(k, LinExpr):
            if k.coeffs and self.coeffs:
                return NonAffine(f"({self}) * ({k})")
            if k.coeffs:                    # self is a constant
                return AffExpr._of(LinExpr.__mul__(k, self.const))
            k = k.const
        if isinstance(k, float):
            if not k.is_integer():
                return NonAffine(f"({self}) * {k!r}")
            k = int(k)
        if not isinstance(k, int):
            return NonAffine(f"({self}) * {k!r}")
        return AffExpr._of(LinExpr.__mul__(self, k))

    __rmul__ = __mul__


@dataclass(frozen=True)
class ArrayRef:
    """A declared array: its name and shape.  Subscription builds an
    :class:`AccessRef` — ``A[i, j + 1]`` — for `Nest.stmt` read/write lists;
    the declared shape is also the domain of the derived boundary process."""

    name: str
    shape: Tuple[object, ...]      # int extents, or LinExpr over parameters

    def __getitem__(self, idx) -> "AccessRef":
        return AccessRef(self, idx if isinstance(idx, tuple) else (idx,))

    def __repr__(self) -> str:
        return f"{self.name}{list(self.shape)}"


@dataclass(frozen=True)
class AccessRef:
    """An array subscription as written by the author — indices are kept raw
    (affine expressions, ints, or poison) until `Nest.stmt` validates them."""

    array: ArrayRef
    idx: Tuple[object, ...]

    def __repr__(self) -> str:
        return f"{self.array.name}[{', '.join(map(repr, self.idx))}]"


@dataclass
class _OpenLoop:
    name: str
    cons: List[Constraint]
    position: int
    children: List[Tuple[int, str]] = field(default_factory=list)
    auto: int = 0


@dataclass
class _BodyStmt:
    name: str
    dims: Tuple[str, ...]
    domain: List[Constraint]
    path: Tuple[int, ...]                  # positions: one per level + own
    writes: List[Access]
    reads: List[Access]


class _LoopCtx:
    """Context manager returned by `Nest.loop`; registration (parent,
    position, bound validation) happens at ``__enter__`` so the loop tree
    mirrors the actual ``with`` nesting."""

    def __init__(self, nest: "Nest", name: str, lo, hi, at: Optional[int]):
        self._nest, self._name = nest, name
        self._lo, self._hi, self._at = lo, hi, at

    def __enter__(self) -> AffExpr:
        return self._nest._enter_loop(self._name, self._lo, self._hi,
                                      self._at)

    def __exit__(self, *exc) -> None:
        self._nest._exit_loop()


class Nest:
    """One kernel under construction — see the module docstring for the
    authoring model and `build()` / `case()` for the compiled products."""

    def __init__(self, name: str):
        self.name = name
        self._params: Dict[str, int] = {}
        self._arrays: Dict[str, ArrayRef] = {}
        self._stack: List[_OpenLoop] = []
        self._all_loops: List[_OpenLoop] = []
        self._root = _OpenLoop("<program>", [], -1)
        self._stmts: List[_BodyStmt] = []
        self._inputs: Optional[List[str]] = None
        self._outputs: List[str] = []
        self._tilings: Dict[str, Tiling] = {}
        self._diags: List[str] = []
        self._kernel: Optional[Kernel] = None

    # ------------------------------------------------------------ authoring

    def param(self, name: str, default: int) -> AffExpr:
        """Declare a symbolic size parameter with its concrete default.

        The returned expression composes into loop bounds, array extents and
        where-clauses like any iterator.  The default is baked into the
        compiled ``Kernel.params`` so every concrete path (enumeration,
        validation, golden fixtures) behaves exactly as if the sizes were
        literal; parametric analysis (``analyze(k, sizes=symbolic)``) keeps
        the name symbolic instead."""
        d = int(default)
        if name in self._params:
            if self._params[name] != d:
                self._diags.append(
                    f"parameter {name!r}: redeclared with a different "
                    f"default ({self._params[name]} vs {d})")
            return AffExpr.var(name)
        if d <= 0:
            self._diags.append(f"parameter {name!r}: default must be "
                               f"positive (got {d})")
        self._params[name] = d
        self._kernel = None
        return AffExpr.var(name)

    def array(self, name: str, *shape) -> ArrayRef:
        """Declare an array with its extents (each dimension ``[0, ext)``).
        Extents are integers or affine expressions over declared
        parameters."""
        if name in self._arrays:
            raise ValueError(f"array {name!r} already declared")
        exts: List[object] = []
        for e in shape:
            co = _coerce(e)
            if isinstance(co, NonAffine):
                self._diags.append(f"array {name!r}: non-affine extent "
                                   f"{co.reason}")
                exts.append(1)
            elif co.coeffs:
                bad = [nm for nm in co.vars() if nm not in self._params]
                if bad:
                    self._diags.append(
                        f"array {name!r}: extent {co!r} references "
                        f"non-parameter variable"
                        f"{'s' if len(bad) > 1 else ''} "
                        + ", ".join(map(repr, bad)))
                exts.append(co)
            else:
                exts.append(int(co.const))
        ref = ArrayRef(name, tuple(exts))
        self._arrays[name] = ref
        self._kernel = None
        return ref

    def loop(self, name: str, lo, hi, at: Optional[int] = None) -> _LoopCtx:
        """Open a loop ``for name in [lo, hi)`` (bounds affine in outer
        iterators); use as ``with k.loop("i", 0, N) as i:``.  ``at=`` pins
        the loop's program-order position among its siblings."""
        return _LoopCtx(self, name, lo, hi, at)

    def stmt(self, name: str, writes=None, reads=None,
             where: Sequence[Constraint] = (),
             at: Optional[int] = None) -> str:
        """Add a statement at the current loop nesting.  ``writes`` /
        ``reads`` are access lists (`A[i, j]`-style, a single access is
        accepted bare); ``where`` adds extra affine guards to the domain;
        ``at=`` pins the program-order position.  Returns the statement name
        (the handle `tile()` takes)."""
        if any(s.name == name for s in self._stmts):
            self._diags.append(f"statement {name!r}: duplicate statement "
                               f"name")
        parent = self._stack[-1] if self._stack else self._root
        position = self._place(parent, name, at)
        dims = tuple(l.name for l in self._stack)
        domain = [c for l in self._stack for c in l.cons]
        for c in where:
            if not isinstance(c, Constraint):
                self._diags.append(f"statement {name!r}: where-clause entry "
                                   f"{c!r} is not a Constraint")
                continue
            self._check_scope(name, c.expr, f"where-clause {c!r}", dims)
            domain.append(c)
        out = _BodyStmt(name, dims, domain,
                        tuple([l.position for l in self._stack] + [position]),
                        self._accesses(name, "write", writes, dims),
                        self._accesses(name, "read", reads, dims))
        self._stmts.append(out)
        self._kernel = None
        return name

    def inputs(self, *arrays: Union[ArrayRef, str]) -> "Nest":
        """Declare the loaded arrays, in load order (each becomes a
        ``load_<name>`` prologue process).  Without this call, arrays whose
        first access in program order is a read are loaded, in first-read
        order."""
        self._inputs = [self._array_name("inputs", a) for a in arrays]
        self._kernel = None
        return self

    def outputs(self, *arrays: Union[ArrayRef, str]) -> "Nest":
        """Declare the stored arrays, in store order (each becomes a
        ``store_<name>`` epilogue process).  Liveness is not derivable from
        the spec, so outputs are always explicit."""
        self._outputs = [self._array_name("outputs", a) for a in arrays]
        self._kernel = None
        return self

    def tile(self, stmt: str, tiling: Tiling) -> "Nest":
        """Attach a `Tiling` to one statement (the per-statement embedding
        into the common tile space — see `core.tiling.Tiling`)."""
        self._tilings[str(stmt)] = tiling
        self._kernel = None
        return self

    # ----------------------------------------------------------- internals

    def _array_name(self, who: str, a: Union[ArrayRef, str]) -> str:
        name = a.name if isinstance(a, ArrayRef) else str(a)
        if name not in self._arrays:
            self._diags.append(f"{who}: unknown array {name!r} (declare it "
                               f"with Nest.array first)")
        return name

    def _place(self, parent: _OpenLoop, name: str, at: Optional[int]) -> int:
        if at is None:
            position = parent.auto
            parent.auto += 1
        else:
            position = int(at)
            if position < 0 and parent is self._root:
                # only the ROOT position becomes the schedule's leading c0;
                # keeping it non-negative reserves the prologue phase
                # (c0 = PROLOGUE_C0) for derived load processes.  Interior
                # positions may go negative freely (ordering before auto-
                # positioned siblings).
                self._diags.append(
                    f"{name!r}: top-level position at={position} is "
                    f"negative (negative phases are reserved for derived "
                    f"load processes)")
            parent.auto = max(parent.auto, position + 1)
        parent.children.append((position, name))
        return position

    def _enter_loop(self, name: str, lo, hi, at: Optional[int]) -> AffExpr:
        parent = self._stack[-1] if self._stack else self._root
        open_names = tuple(l.name for l in self._stack)
        if name in open_names:
            self._diags.append(f"loop {name!r}: shadows an open loop of the "
                               f"same name (open loops: "
                               f"{', '.join(open_names)})")
        if name in self._params:
            self._diags.append(f"loop {name!r}: shadows the parameter of "
                               f"the same name")
        cons: List[Constraint] = []
        bounds = []
        for label, bound in (("lower", lo), ("upper", hi)):
            e = _coerce(bound)
            if isinstance(e, NonAffine):
                self._diags.append(f"loop {name!r}: non-affine {label} "
                                   f"bound {e.reason}")
                e = LinExpr.const_expr(0)
            else:
                self._check_scope(f"loop {name!r}", e,
                                  f"{label} bound", open_names, kind="loop")
            bounds.append(e)
        cons.append(ge(v(name), bounds[0]))
        cons.append(lt(v(name), bounds[1]))
        position = self._place(parent, name, at)
        record = _OpenLoop(name, cons, position)
        self._stack.append(record)
        self._all_loops.append(record)
        self._kernel = None
        return AffExpr.var(name)

    def _exit_loop(self) -> None:
        self._stack.pop()

    def _check_scope(self, owner: str, expr: LinExpr, what: str,
                     dims: Sequence[str], kind: str = "statement") -> None:
        for name in expr.vars():
            if name not in dims and name not in self._params:
                scope = ", ".join(dims) if dims else "none"
                label = owner if kind == "loop" else f"statement {owner!r}"
                self._diags.append(
                    f"{label}: {what} references out-of-scope iterator "
                    f"{name!r} (open loops: {scope})")

    def _accesses(self, stmt: str, what: str, refs,
                  dims: Sequence[str]) -> List[Access]:
        if refs is None:
            return []
        if isinstance(refs, AccessRef):
            refs = [refs]
        out: List[Access] = []
        for ref in refs:
            if not isinstance(ref, AccessRef):
                self._diags.append(f"statement {stmt!r}: {what} {ref!r} is "
                                   f"not an array access (use A[i, j])")
                continue
            arr = ref.array
            if self._arrays.get(arr.name) is not arr:
                self._diags.append(f"statement {stmt!r}: {what} of array "
                                   f"{arr.name!r} not declared on this Nest")
            if len(ref.idx) != len(arr.shape):
                self._diags.append(
                    f"statement {stmt!r}: {what} {ref!r} has "
                    f"{len(ref.idx)} indices for {len(arr.shape)}-d array "
                    f"{arr.name!r}")
            fn: List[LinExpr] = []
            for ix in ref.idx:
                e = _coerce(ix)
                if isinstance(e, NonAffine):
                    self._diags.append(f"statement {stmt!r}: non-affine "
                                       f"index {e.reason} in {what} {ref!r}")
                    e = LinExpr.const_expr(0)
                else:
                    self._check_scope(stmt, e, f"{what} {ref!r}", dims)
                fn.append(e)
            out.append(Access(arr.name, tuple(fn)))
        return out

    # ---------------------------------------------------------- validation

    def validate(self) -> List[str]:
        """Every diagnostic for the spec as authored so far (empty = valid).
        `build()` raises `SpecError` listing these instead of letting a
        malformed spec surface as a downstream numpy error."""
        diags = list(self._diags)
        if self._stack:
            diags.append(f"loop {self._stack[-1].name!r}: still open at "
                         f"build time (build() inside the with-block?)")
        diags += self._collision_diags()
        diags += self._domain_diags()
        body_names = {s.name for s in self._stmts}
        for name, tiling in self._tilings.items():
            if name not in body_names:
                diags.append(f"tiling attached to unknown statement "
                             f"{name!r}")
                continue
            stmt = next(s for s in self._stmts if s.name == name)
            for row in tiling.normals:
                if len(row) != len(stmt.dims):
                    diags.append(
                        f"statement {name!r}: tiling normal {tuple(row)} "
                        f"has {len(row)} entries for {len(stmt.dims)} loop "
                        f"dims {stmt.dims}")
        seen_boundary: set = set()
        for bname in self._boundary_names():
            if bname in body_names:
                diags.append(f"statement {bname!r}: name collides with a "
                             f"derived boundary process")
            if bname in seen_boundary:
                diags.append(f"boundary process {bname!r} duplicated (array "
                             f"listed more than once in inputs()/outputs())")
            seen_boundary.add(bname)
        return diags

    def _collision_diags(self) -> List[str]:
        """Two siblings pinned (via ``at=``) to the same program-order
        position have colliding schedules — the program order is ambiguous."""
        diags: List[str] = []
        for cont in [self._root] + self._all_loops:
            seen: Dict[int, str] = {}
            for position, child in cont.children:
                if position in seen:        # same-named siblings collide too
                    diags.append(
                        f"schedule collision under "
                        f"{'the program' if cont is self._root else f'loop {cont.name!r}'}: "
                        f"{seen[position]!r} and {child!r} both at "
                        f"position {position}")
                seen.setdefault(position, child)
        return diags

    def _domain_diags(self) -> List[str]:
        diags: List[str] = []
        # validate at the parameter defaults: the spec checks (emptiness,
        # boundedness) are concrete-size questions and the defaults are the
        # sizes every concrete path will use
        env = {p: LinExpr.const_expr(d) for p, d in self._params.items()}
        for s in self._stmts:
            dom = ([c.substitute(env) for c in s.domain] if env
                   else s.domain)
            poly = Polyhedron(dom)
            if poly.is_empty():
                diags.append(f"statement {s.name!r}: empty iteration domain "
                             f"(no integer point satisfies its bounds)")
                continue
            if s.dims:
                try:
                    box = poly.bounding_box()
                    unbounded = [d for d in s.dims if d not in box]
                except ValueError:
                    # a ray leaked — usually a free variable from an
                    # out-of-scope reference (diagnosed above), so don't
                    # blame the (possibly well-bounded) loop iterators
                    diags.append(f"statement {s.name!r}: iteration domain "
                                 f"has an unbounded direction (does a bound "
                                 f"or where-clause reference a free "
                                 f"variable?)")
                    continue
                if unbounded:
                    diags.append(f"statement {s.name!r}: iterator"
                                 f"{'s' if len(unbounded) > 1 else ''} "
                                 f"{', '.join(map(repr, unbounded))} "
                                 f"unbounded (every loop needs finite "
                                 f"bounds)")
        return diags

    # ---------------------------------------------------------- compilation

    def _boundary_names(self) -> List[str]:
        return ([f"load_{a}" for a in self._derived_inputs()]
                + [f"store_{a}" for a in self._outputs])

    def _derived_inputs(self) -> List[str]:
        if self._inputs is not None:
            return list(self._inputs)
        first: Dict[str, str] = {}
        for s in sorted(self._stmts, key=lambda s: s.path):
            for acc in s.reads:
                first.setdefault(acc.array, "read")
            for acc in s.writes:
                first.setdefault(acc.array, "write")
        return [a for a, kind in first.items() if kind == "read"]

    def _schedule(self, s: _BodyStmt) -> AffineSchedule:
        """The 2d+1 timestamp from program order: position constants
        interleaved with the loop counters — nothing hand-numbered."""
        exprs: List[LinExpr] = [LinExpr.const_expr(s.path[0])]
        for level, dim in enumerate(s.dims):
            exprs.append(LinExpr.var(dim))
            exprs.append(LinExpr.const_expr(s.path[level + 1]))
        return AffineSchedule(s.dims, exprs)

    def _boundary(self, arr: str, rank: int, c0: int,
                  prefix: str) -> Statement:
        shape = self._arrays[arr].shape
        dims = tuple(f"{prefix[0]}{k}" for k in range(len(shape)))
        dom: List[Constraint] = []
        for d, ext in zip(dims, shape):
            dom += [ge(v(d), LinExpr.const_expr(0)),
                    lt(v(d), LinExpr.coerce(ext))]
        access = [Access(arr, tuple(LinExpr.var(d) for d in dims))]
        kwargs = ({"writes": access} if prefix == "load" else
                  {"reads": access})
        return Statement(f"{prefix}_{arr}", dims, dom,
                         boundary_schedule(dims, c0, rank), **kwargs)

    def build(self) -> Kernel:
        """Validate and compile to a `Kernel` (cached until the spec is
        touched again); raises `SpecError` on any diagnostic."""
        if self._kernel is not None:
            return self._kernel
        diags = self.validate()
        if diags:
            raise SpecError(diags)
        loads = [self._boundary(a, rank, PROLOGUE_C0, "load")
                 for rank, a in enumerate(self._derived_inputs())]
        body = [Statement(s.name, s.dims, list(s.domain), self._schedule(s),
                          writes=list(s.writes), reads=list(s.reads))
                for s in self._stmts]
        epi = epilogue_c0(p for p, _ in self._root.children)
        stores = [self._boundary(a, rank, epi, "store")
                  for rank, a in enumerate(self._outputs)]
        self._kernel = Kernel(self.name, dict(self._params),
                              loads + body + stores,
                              arrays={n: r.shape
                                      for n, r in self._arrays.items()})
        return self._kernel

    # ----------------------------------------------------------- packaging

    @property
    def kernel(self) -> Kernel:
        return self.build()

    @property
    def tilings(self) -> Dict[str, Tiling]:
        return dict(self._tilings)

    def case(self, compute: Optional[Sequence[str]] = None,
             notes: str = "") -> KernelCase:
        """Package as a `KernelCase`; ``compute`` defaults to every body
        statement in program order (the processes the paper's tables count
        channels between)."""
        kernel = self.build()
        if compute is None:
            compute = tuple(s.name for s in self._stmts)
        return KernelCase(kernel, dict(self._tilings), tuple(compute), notes)

    def __kernelcase__(self) -> KernelCase:
        """Protocol hook: `analyze()` / `sweep()` / the kernel registry call
        this to accept builder programs directly."""
        return self.case()
