"""Declarative kernel-authoring frontend (`docs/frontend.md`).

    from repro.lang import Nest

`Nest` builds loop-nest programs with operator-overloaded affine index
expressions, compiles them to the polyhedral core's `Kernel`/`KernelCase`
(automatic 2d+1 schedules from program order, derived load/store boundary
processes, per-statement tilings), and validates specs with actionable
diagnostics (`SpecError`).  `analyze()` / `sweep()` and the kernel registry
accept `Nest` programs directly via the ``__kernelcase__()`` protocol.

``python -m repro.lang --check-registry`` validates every registered kernel
spec (CI runs it before any analysis timing section).
"""
from .builder import (AccessRef, AffExpr, ArrayRef, Nest, NonAffine,
                      SpecError)
from .check import check_registry

__all__ = ["AccessRef", "AffExpr", "ArrayRef", "Nest", "NonAffine",
           "SpecError", "check_registry"]
