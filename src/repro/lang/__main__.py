"""``python -m repro.lang --check-registry [names...]`` — spec validation.

Exit status 0 when every registered kernel spec builds and validates; 1
with one line per diagnostic otherwise.  CI runs this before any analysis
timing section so malformed specs fail fast with authoring-level errors.
"""
from __future__ import annotations

import argparse
import sys

from .check import check_registry


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.lang")
    ap.add_argument("--check-registry", action="store_true",
                    help="build + validate every registered kernel spec")
    ap.add_argument("names", nargs="*",
                    help="restrict the check to these registry names")
    ap.add_argument("--scale", type=int, default=1,
                    help="structure-parameter scale to build at")
    args = ap.parse_args(argv)
    if not args.check_registry:
        ap.error("nothing to do (pass --check-registry)")
    failures = check_registry(args.names or None, scale=args.scale)
    from ..core.registry import kernel_names
    checked = args.names or kernel_names()
    if failures:
        for f in failures:
            print(f"FAIL: {f}", file=sys.stderr)
        print(f"registry check: {len(failures)} failure(s) across "
              f"{len(checked)} kernel(s)", file=sys.stderr)
        return 1
    print(f"registry check: {len(checked)} kernel spec(s) valid")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
