"""Registry-wide spec validation — the fail-fast gate CI runs first.

Every registered kernel factory is built (a `repro.lang` program compiles
and validates here; `SpecError` diagnostics become failures) and the
resulting case is sanity-checked frontend-agnostically: compute names and
tiling targets must be real statements, tiling normals must match statement
dimensionality.  Malformed specs fail HERE, with spec-level diagnostics,
before any analysis or benchmark timing section touches them.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

from .builder import SpecError


def check_case(name: str, case) -> List[str]:
    """Frontend-agnostic sanity diagnostics for one resolved KernelCase."""
    failures: List[str] = []
    stmts = {s.name: s for s in case.kernel.statements}
    if len(stmts) != len(case.kernel.statements):
        failures.append(f"{name}: duplicate statement names in kernel")
    for cname in case.compute:
        if cname not in stmts:
            failures.append(f"{name}: compute process {cname!r} is not a "
                            f"statement of the kernel")
    for sname, tiling in case.tilings.items():
        if sname not in stmts:
            failures.append(f"{name}: tiling attached to unknown statement "
                            f"{sname!r}")
            continue
        d = len(stmts[sname].dims)
        for row in tiling.normals:
            if len(row) != d:
                failures.append(f"{name}: tiling normal {tuple(row)} of "
                                f"{sname!r} has {len(row)} entries for "
                                f"{d} loop dims")
    return failures


def check_registry(names: Optional[Sequence[str]] = None,
                   scale: int = 1) -> List[str]:
    """Build + validate every registered kernel; returns failure strings
    (empty = all specs valid)."""
    from ..core import registry
    # ensure the built-in suite is registered before walking the registry
    from ..core import polybench  # noqa: F401

    failures: List[str] = []
    for name in (registry.kernel_names() if names is None else names):
        try:
            case = registry.get(name, scale)
        except SpecError as e:
            failures.extend(f"{name}: {d}" for d in e.diagnostics)
            continue
        except Exception as e:                      # registry must not crash
            failures.append(f"{name}: {type(e).__name__}: {e}")
            continue
        failures.extend(check_case(name, case))
    return failures
